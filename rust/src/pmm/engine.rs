//! The full GCN training/eval engine over the 3D PMM primitives — the
//! per-rank body executed by every thread of a data-parallel group.
//!
//! Forward follows §IV-C (Fig. 4): parallel input projection, per layer a
//! parallel SpMM (Eq. 27) + GEMM (Eq. 28) + parallel RMSNorm (Eq. 29) +
//! ReLU/dropout (local) + resharded residual; parallel masked cross-entropy
//! over the class-sharded logits.  Backward mirrors it with the transposed
//! primitives (Eqs. 13-19).  Weight shards are updated by a rank-local Adam
//! (replicas stay in sync because their gradients are identical after the
//! contraction + DP all-reduces).
//!
//! Backward executes the §V-D communication/computation overlap: every
//! parameter-gradient contraction all-reduce is *issued* into the
//! nonblocking chunked collective engine the moment its local partial
//! product exists, landed gradients immediately become per-layer DP
//! buckets, and waits happen only at true data dependencies (the RMSNorm
//! dot, dH, dF and the optimizer).  `set_overlap(false)` resolves each
//! handle at its issue point instead — the blocking Fig. 5 baseline —
//! with bitwise-identical results (the engine reduces in group-index
//! order), so the measured step-time delta is pure overlap.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use super::{feature_layouts, shard_dropout_mask, Layout, PendingMat, PendingVec, PmmCtx, PmmMat};
use crate::comm::Precision;
use crate::graph::{block_bounds, partition::extract_shard, Dataset};
use crate::grid::Axis;
use crate::model::GcnDims;
use crate::model::{ADAM_B1, ADAM_B2, ADAM_EPS};
use crate::sampling::{DistributedSubgraphBuilder, LocalSubgraph, UniformVertexSampler};
use crate::tensor::Mat;
use crate::util::rng::Rng;

/// Per-phase wall-clock accumulators (seconds) — feeds the Fig. 5 / Fig. 8
/// breakdowns measured at real (small) scale.
#[derive(Clone, Copy, Debug, Default)]
pub struct PmmTimers {
    /// Blocking wait on Algorithm-2 subgraph construction.
    pub sampling: f64,
    /// Rank-local sparse aggregation kernels.
    pub spmm: f64,
    /// Rank-local dense matmul kernels.
    pub gemm: f64,
    /// RMSNorm / ReLU / dropout / residual element-wise work.
    pub elementwise: f64,
    /// Tensor-parallel collectives (contraction/RMSNorm all-reduces).
    pub tp_comm: f64,
    /// Data-parallel gradient all-reduce.
    pub dp_comm: f64,
    /// Residual-resharding all-gathers (§IV-C4).
    pub reshard: f64,
    /// Everything else (input shard gather, Adam, bookkeeping).
    pub other: f64,
}

impl PmmTimers {
    /// Sum of all phases.
    pub fn total(&self) -> f64 {
        self.sampling
            + self.spmm
            + self.gemm
            + self.elementwise
            + self.tp_comm
            + self.dp_comm
            + self.reshard
            + self.other
    }

    /// Accumulate another rank's (or step's) timers into this one.
    pub fn add(&mut self, o: &PmmTimers) {
        self.sampling += o.sampling;
        self.spmm += o.spmm;
        self.gemm += o.gemm;
        self.elementwise += o.elementwise;
        self.tp_comm += o.tp_comm;
        self.dp_comm += o.dp_comm;
        self.reshard += o.reshard;
        self.other += o.other;
    }
}

/// Loss/accuracy of one engine training step (identical on every rank of a
/// DP group after the loss all-reduces).
pub struct PmmStepOutput {
    /// Masked mean cross-entropy over the sampled train vertices.
    pub loss: f32,
    /// Masked accuracy over the sampled train vertices.
    pub acc: f32,
}

/// Compact row bounds over [0,B) induced by intersecting the sorted sample
/// with the static vertex ranges of `axis_size` blocks (identical on every
/// rank — no communication).
fn compact_bounds(sample: &[u32], n: usize, axis_size: usize) -> Vec<usize> {
    let vb = block_bounds(n, axis_size);
    vb.iter()
        .map(|&v| sample.partition_point(|&s| (s as usize) < v))
        .collect()
}

struct LayerCacheP {
    f_in: PmmMat,
    h_agg: PmmMat,
    xc: PmmMat,
    inv: Vec<f32>,
    mask: Mat,
    adj: LocalSubgraph,
}

/// §V-A sampling/compute overlap for the PMM engine: a dedicated thread
/// owns the per-layer Algorithm-2 builders and constructs the subgraphs of
/// step `t+1` while the rank computes step `t`.  Builders are deterministic
/// per step, so speculative results are always valid; out-of-order step
/// requests (rare, tests only) fall back to an on-demand build.
struct SubgraphPrefetcher {
    req_tx: Option<Sender<u64>>,
    res_rx: Receiver<(u64, Vec<LocalSubgraph>)>,
    /// spent subgraph shells flowing back to the builder thread for reuse
    free_tx: Sender<Vec<LocalSubgraph>>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// a finished speculative result not yet consumed
    pending: Option<(u64, Vec<LocalSubgraph>)>,
    /// the step of the newest request sent but not yet received
    in_flight: Option<u64>,
}

impl SubgraphPrefetcher {
    fn new(mut builders: Vec<DistributedSubgraphBuilder>) -> SubgraphPrefetcher {
        let (req_tx, req_rx) = channel::<u64>();
        let (res_tx, res_rx) = channel::<(u64, Vec<LocalSubgraph>)>();
        let (free_tx, free_rx) = channel::<Vec<LocalSubgraph>>();
        let handle = std::thread::spawn(move || {
            while let Ok(step) = req_rx.recv() {
                // reuse a recycled shell set when one has come back; the
                // builders then run allocation-free (`build_into`)
                let mut subs = free_rx.try_recv().unwrap_or_default();
                subs.resize_with(builders.len(), LocalSubgraph::empty);
                for (b, out) in builders.iter_mut().zip(subs.iter_mut()) {
                    b.build_into(step, out);
                }
                if res_tx.send((step, subs)).is_err() {
                    break; // engine dropped
                }
            }
        });
        SubgraphPrefetcher {
            req_tx: Some(req_tx),
            res_rx,
            free_tx,
            handle: Some(handle),
            pending: None,
            in_flight: None,
        }
    }

    /// Hand a spent step's subgraphs (plus the sample that was moved out
    /// of slot 0) back to the builder thread for buffer reuse.  Fire and
    /// forget: a closed channel (worker already exited) just drops them.
    fn recycle(&self, mut subs: Vec<LocalSubgraph>, sample: Vec<u32>) {
        if let Some(s0) = subs.get_mut(0) {
            s0.sample = sample;
        }
        let _ = self.free_tx.send(subs);
    }

    /// Blocking fetch of step `step`'s subgraphs; afterwards requests
    /// `step+1` speculatively so its construction overlaps this step's
    /// compute.  The blocking time (what `timers.sampling` measures) is
    /// ~zero once the pipeline is warm.
    fn take(&mut self, step: u64) -> Vec<LocalSubgraph> {
        let tx = self.req_tx.as_ref().expect("prefetcher closed");
        // park a finished speculative result, if any
        if self.pending.is_none() {
            if let Ok(r) = self.res_rx.try_recv() {
                if Some(r.0) == self.in_flight {
                    self.in_flight = None;
                }
                self.pending = Some(r);
            }
        }
        let hit = matches!(&self.pending, Some((s, _)) if *s == step);
        let subs = if hit {
            self.pending.take().expect("checked above").1
        } else {
            self.pending = None;
            if self.in_flight != Some(step) {
                tx.send(step).expect("subgraph prefetcher died");
            }
            self.in_flight = None;
            loop {
                match self.res_rx.recv() {
                    Ok((s, subs)) if s == step => break subs,
                    Ok(_) => continue, // stale speculative result
                    Err(_) => panic!("subgraph prefetcher died"),
                }
            }
        };
        if tx.send(step + 1).is_ok() {
            self.in_flight = Some(step + 1);
        }
        subs
    }
}

impl Drop for SubgraphPrefetcher {
    fn drop(&mut self) {
        self.req_tx.take(); // closes the channel; worker drains and exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A parameter-gradient all-reduce in flight on a tensor-parallel axis
/// (§V-D): either a sharded matrix gradient or a flat scale-vector
/// gradient.  Only the flat data reaches the optimizer.
enum PendingTpGrad<'w> {
    Mat(PendingMat<'w>),
    Vec(PendingVec<'w>),
}

impl PendingTpGrad<'_> {
    fn try_ready(&self) -> bool {
        match self {
            PendingTpGrad::Mat(p) => p.try_ready(),
            PendingTpGrad::Vec(p) => p.try_ready(),
        }
    }

    fn wait(self) -> Vec<f32> {
        match self {
            PendingTpGrad::Mat(p) => p.wait().local.data,
            PendingTpGrad::Vec(p) => p.wait(),
        }
    }
}

/// Drain the head of the TP-pending gradient queue in its fixed issue
/// order: every landed contraction all-reduce immediately becomes a
/// per-layer data-parallel gradient bucket (`issue_dp`) or, with `Gd = 1`,
/// a finished gradient.  With `block` the whole queue is resolved; with
/// `dp_blocking` (the overlap-off baseline) each DP bucket is also waited
/// at its issue point instead of being queued.  The fixed order keeps the
/// DP issue sequence identical on every rank of a DP group (the collective
/// engine matches collectives by sequence number, so issue order may never
/// depend on completion timing).
fn drain_tp_queue<'w>(
    ctx: &PmmCtx<'w>,
    tp_queue: &mut VecDeque<(usize, PendingTpGrad<'w>)>,
    dp_queue: &mut VecDeque<(usize, PendingVec<'w>)>,
    grads: &mut [Option<Vec<f32>>],
    block: bool,
    dp_blocking: bool,
    timers: &mut PmmTimers,
) {
    let gd = ctx.grid.gd as f32;
    loop {
        let ready = match tp_queue.front() {
            None => break,
            Some((_, p)) => block || p.try_ready(),
        };
        if !ready {
            break;
        }
        let (slot, p) = tp_queue.pop_front().expect("checked non-empty");
        let t0 = std::time::Instant::now();
        let data = p.wait();
        timers.tp_comm += t0.elapsed().as_secs_f64();
        if gd > 1.0 {
            let t0 = std::time::Instant::now();
            let pend = ctx.issue_dp(data);
            if dp_blocking {
                let mut data = pend.wait();
                for v in data.iter_mut() {
                    *v /= gd;
                }
                grads[slot] = Some(data);
            } else {
                dp_queue.push_back((slot, pend));
            }
            timers.dp_comm += t0.elapsed().as_secs_f64();
        } else {
            grads[slot] = Some(data);
        }
    }
}

/// Queue a just-issued parameter-gradient all-reduce; on the overlap-off
/// baseline (`overlap == false`) resolve it — and its DP bucket — right
/// here at the issue point, reproducing the fully blocking schedule.
#[allow(clippy::too_many_arguments)]
fn stage_tp_grad<'w>(
    ctx: &PmmCtx<'w>,
    overlap: bool,
    slot: usize,
    pending: PendingTpGrad<'w>,
    tp_queue: &mut VecDeque<(usize, PendingTpGrad<'w>)>,
    dp_queue: &mut VecDeque<(usize, PendingVec<'w>)>,
    grads: &mut [Option<Vec<f32>>],
    timers: &mut PmmTimers,
) {
    tp_queue.push_back((slot, pending));
    if !overlap {
        drain_tp_queue(ctx, tp_queue, dp_queue, grads, true, true, timers);
    }
}

/// One rank's engine state.
pub struct PmmGcn<'a> {
    /// This rank's grid/communication context.
    pub ctx: PmmCtx<'a>,
    /// Model dimensions.
    pub dims: GcnDims,
    /// Mini-batch size `B`.
    pub batch: usize,
    /// The (shared, in-memory) dataset.
    pub data: Arc<Dataset>,
    /// Base seed for parameters, sampling and dropout streams.
    pub seed: u64,
    f_layouts: Vec<Layout>,
    // parameters (sharded); g is a replicated local slice over the layer's
    // feature column axis
    w_in: PmmMat,
    w: Vec<PmmMat>,
    g: Vec<Vec<f32>>,
    w_out: PmmMat,
    // adam moments per local shard, ordered [w_in, (w_l, g_l)*, w_out]
    adam_m: Vec<Vec<f32>>,
    adam_v: Vec<Vec<f32>>,
    t: f32,
    prefetcher: SubgraphPrefetcher,
    // reduction scratch reused across layers and steps (RMSNorm backward)
    scratch_dots: Vec<f32>,
    scratch_dxn: Vec<f32>,
    /// §V-D backward communication/computation overlap (on by default).
    overlap: bool,
    /// Per-phase wall-clock accumulated over all steps run so far.
    pub timers: PmmTimers,
}

macro_rules! timed {
    ($self:ident . $field:ident, $e:expr) => {{
        let __t = std::time::Instant::now();
        let __r = $e;
        $self.timers.$field += __t.elapsed().as_secs_f64();
        __r
    }};
}

impl<'a> PmmGcn<'a> {
    /// Build one rank's engine: shard the (shared-seed) parameters, size
    /// the Adam moments, and start the per-layer Algorithm-2 prefetcher.
    pub fn new(
        ctx: PmmCtx<'a>,
        dims: GcnDims,
        batch: usize,
        data: Arc<Dataset>,
        seed: u64,
    ) -> PmmGcn<'a> {
        let f_layouts = feature_layouts(dims.layers);
        // full parameters from a shared seed, then slice local shards
        let mut rng = Rng::new(seed ^ 0x9A7A);
        let shapes = dims.param_shapes();
        let full: Vec<Mat> = shapes
            .iter()
            .map(|&(r, c)| {
                if r == 1 && c == dims.d_h {
                    Mat::filled(r, c, 1.0)
                } else {
                    Mat::glorot(r, c, &mut rng)
                }
            })
            .collect();
        let w_in = ctx.shard_from_global(&full[0], Layout::new(Axis::Z, Axis::Y));
        let mut w = Vec::new();
        let mut g = Vec::new();
        for l in 0..dims.layers {
            let fl = f_layouts[l];
            // W_l on (C_l, R_l); g_l sliced over R_l (the post-GEMM col axis)
            w.push(ctx.shard_from_global(
                &full[1 + 2 * l],
                Layout::new(fl.col_axis, fl.row_axis),
            ));
            let gb = block_bounds(dims.d_h, ctx.axis_size(fl.row_axis));
            let gi = ctx.axis_coord(fl.row_axis);
            g.push(full[2 + 2 * l].data[gb[gi]..gb[gi + 1]].to_vec());
        }
        let fl_last = f_layouts[dims.layers];
        let w_out = ctx.shard_from_global(
            &full[shapes.len() - 1],
            Layout::new(fl_last.col_axis, fl_last.third()),
        );

        // adam moments sized per local shard
        let mut locals: Vec<usize> = vec![w_in.local.data.len()];
        for l in 0..dims.layers {
            locals.push(w[l].local.data.len());
            locals.push(g[l].len());
        }
        locals.push(w_out.local.data.len());
        let adam_m: Vec<Vec<f32>> = locals.iter().map(|&n| vec![0.0; n]).collect();
        let adam_v = adam_m.clone();

        // per-layer adjacency builders: A^(l) on (third_l rows, R_l cols).
        // Each DP group draws an independent mini-batch stream (§IV-A), so
        // the sampler seed is keyed on the group's d coordinate; ranks
        // within a group share it (the communication-free contract).
        let group_seed = crate::util::rng::splitmix64(seed ^ (0xD0 + ctx.coord.d as u64));
        let sampler = UniformVertexSampler::new(data.n, batch, group_seed);
        let n = data.n;
        let builders = (0..dims.layers)
            .map(|l| {
                let fl = f_layouts[l];
                let (t_ax, r_ax) = (fl.third(), fl.row_axis);
                let rb = block_bounds(n, ctx.axis_size(t_ax));
                let cb = block_bounds(n, ctx.axis_size(r_ax));
                let (r0, r1) = (rb[ctx.axis_coord(t_ax)], rb[ctx.axis_coord(t_ax) + 1]);
                let (c0, c1) = (cb[ctx.axis_coord(r_ax)], cb[ctx.axis_coord(r_ax) + 1]);
                DistributedSubgraphBuilder::new(
                    sampler.clone(),
                    extract_shard(&data.adj, r0, r1, c0, c1),
                )
            })
            .collect();

        PmmGcn {
            ctx,
            dims,
            batch,
            data,
            seed,
            f_layouts,
            w_in,
            w,
            g,
            w_out,
            adam_m,
            adam_v,
            t: 0.0,
            prefetcher: SubgraphPrefetcher::new(builders),
            scratch_dots: Vec::new(),
            scratch_dxn: Vec::new(),
            overlap: true,
            timers: PmmTimers::default(),
        }
    }

    /// Toggle the §V-D backward communication/computation overlap (on by
    /// default).  Off resolves every gradient all-reduce at its issue
    /// point — the blocking baseline of the Fig. 5 ablation.  Both
    /// schedules are bitwise identical (the collective engine reduces in
    /// group-index order); only the wait placement differs.
    pub fn set_overlap(&mut self, on: bool) {
        self.overlap = on;
    }

    /// Gather the full parameter tensors (validation/debug).
    pub fn gather_params(&self) -> Vec<Mat> {
        let mut out = vec![self.ctx.gather_global(&self.w_in)];
        for l in 0..self.dims.layers {
            out.push(self.ctx.gather_global(&self.w[l]));
            // g: slice over R_l, replicated elsewhere — gather along R_l
            let fl = self.f_layouts[l];
            let parts = self
                .ctx
                .world
                .all_gather(self.ctx.rank, fl.row_axis, &self.g[l], Precision::Fp32);
            out.push(Mat::from_vec(
                1,
                self.dims.d_h,
                parts.into_iter().flatten().collect(),
            ));
        }
        out.push(self.ctx.gather_global(&self.w_out));
        out
    }

    /// Export this rank's shard state for checkpointing: the local
    /// parameter shards in optimizer slot order `[w_in, (w_l, g_l) per
    /// layer, w_out]`, the Adam moments (same order) and the Adam step
    /// counter.  Together with the engine's `(seed, step)` sampler cursor
    /// this is *all* the state a bitwise-identical resume needs — the
    /// subgraph prefetcher and dropout masks are pure functions of
    /// `(seed, step)`, and the prefetcher accepts an arbitrary first step.
    #[allow(clippy::type_complexity)]
    pub fn export_state(&self) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>, f32) {
        let mut tensors = vec![self.w_in.local.data.clone()];
        for l in 0..self.dims.layers {
            tensors.push(self.w[l].local.data.clone());
            tensors.push(self.g[l].clone());
        }
        tensors.push(self.w_out.local.data.clone());
        (tensors, self.adam_m.clone(), self.adam_v.clone(), self.t)
    }

    /// Restore this rank's shard state from an
    /// [`PmmGcn::export_state`]-shaped snapshot.  Every tensor length is
    /// validated against the live shard shapes *before* anything is
    /// written, so a mismatched snapshot leaves the engine untouched.
    pub fn restore_state(
        &mut self,
        tensors: &[Vec<f32>],
        m: &[Vec<f32>],
        v: &[Vec<f32>],
        t: f32,
    ) -> anyhow::Result<()> {
        let lens: Vec<usize> = {
            let mut l = vec![self.w_in.local.data.len()];
            for i in 0..self.dims.layers {
                l.push(self.w[i].local.data.len());
                l.push(self.g[i].len());
            }
            l.push(self.w_out.local.data.len());
            l
        };
        if tensors.len() != lens.len() || m.len() != lens.len() || v.len() != lens.len() {
            anyhow::bail!(
                "rank {}: snapshot has {} tensors, this shard expects {}",
                self.ctx.rank,
                tensors.len(),
                lens.len()
            );
        }
        for (i, &n) in lens.iter().enumerate() {
            if tensors[i].len() != n || m[i].len() != n || v[i].len() != n {
                anyhow::bail!(
                    "rank {}: snapshot tensor {i} has {} elements, this shard expects {n}",
                    self.ctx.rank,
                    tensors[i].len()
                );
            }
        }
        let mut slots: Vec<&mut Vec<f32>> = Vec::with_capacity(lens.len());
        slots.push(&mut self.w_in.local.data);
        for (wl, gl) in self.w.iter_mut().zip(self.g.iter_mut()) {
            slots.push(&mut wl.local.data);
            slots.push(gl);
        }
        slots.push(&mut self.w_out.local.data);
        for (slot, src) in slots.into_iter().zip(tensors) {
            slot.copy_from_slice(src);
        }
        for (dst, src) in self.adam_m.iter_mut().zip(m) {
            dst.copy_from_slice(src);
        }
        for (dst, src) in self.adam_v.iter_mut().zip(v) {
            dst.copy_from_slice(src);
        }
        self.t = t;
        Ok(())
    }

    /// Input features shard for sampled rows (layout (X, Z)).
    fn input_shard(&self, sample: &[u32], cbx: &Arc<Vec<usize>>) -> PmmMat {
        let d_in = self.dims.d_in;
        let col_b = self.ctx.static_bounds(d_in, Axis::Z);
        let (r0, r1) = self.ctx.my_block(cbx, Axis::X);
        let (c0, c1) = self.ctx.my_block(&col_b, Axis::Z);
        let mut local = Mat::zeros(r1 - r0, c1 - c0);
        for (k, &v) in sample[r0..r1].iter().enumerate() {
            let src = &self.data.features.data[v as usize * d_in + c0..v as usize * d_in + c1];
            local.data[k * (c1 - c0)..(k + 1) * (c1 - c0)].copy_from_slice(src);
        }
        PmmMat {
            layout: Layout::new(Axis::X, Axis::Z),
            row_bounds: cbx.clone(),
            col_bounds: col_b,
            local,
        }
    }

    /// Full forward for rows described by per-axis bounds; used by both
    /// train (sampled, step-dependent bounds) and eval (static bounds).
    /// Returns the input-feature shard too so backward can reuse it.
    #[allow(clippy::type_complexity)]
    fn forward_sampled(
        &mut self,
        step: u64,
        train: bool,
    ) -> (PmmMat, Vec<LayerCacheP>, Vec<u32>, PmmMat, PmmMat) {
        let dims = self.dims;
        // Algorithm 2 on every layer's builder runs on the prefetch thread;
        // this measures only the blocking wait (§V-A overlap)
        let mut subs: Vec<LocalSubgraph> =
            timed!(self.sampling, self.prefetcher.take(step));
        // every layer carries the identical sample; move it out instead of
        // cloning (the cached LocalSubgraph only needs its adjacency)
        let sample = std::mem::take(&mut subs[0].sample);
        let n = self.data.n;
        let cb = |ax: Axis| -> Arc<Vec<usize>> {
            Arc::new(compact_bounds(&sample, n, self.ctx.axis_size(ax)))
        };
        let (cbx, cby, cbz) = (cb(Axis::X), cb(Axis::Y), cb(Axis::Z));
        let cb_of = |ax: Axis| match ax {
            Axis::X => cbx.clone(),
            Axis::Y => cby.clone(),
            Axis::Z => cbz.clone(),
            Axis::Dp => unreachable!(),
        };

        // input projection (Fig. 4 left)
        let x_in = timed!(self.other, self.input_shard(&sample, &cbx));
        let mut f = self.ctx.mm(&x_in, &self.w_in);

        let mut caches = Vec::with_capacity(dims.layers);
        for (l, sub) in subs.into_iter().enumerate() {
            let fl = self.f_layouts[l];
            let (t_ax, r_ax) = (fl.third(), fl.row_axis);
            // SpMM aggregation (Eq. 27)
            let h_agg = self.ctx.spmm(&sub.adj, &cb_of(t_ax), t_ax, r_ax, &f);
            // GEMM combination (Eq. 28)
            let xc = self.ctx.mm(&h_agg, &self.w[l]);
            // RMSNorm (Eq. 29) + ReLU + dropout (local)
            let (xn, inv) = self.ctx.rmsnorm_slice(&xc, &self.g[l]);
            let row_off = xc.row_bounds[self.ctx.axis_coord(xc.layout.row_axis)];
            let col_off = xc.col_bounds[self.ctx.axis_coord(xc.layout.col_axis)];
            let mask = if train && dims.dropout > 0.0 {
                shard_dropout_mask(
                    self.seed,
                    step,
                    l,
                    xn.local.rows,
                    xn.local.cols,
                    row_off,
                    col_off,
                    dims.d_h,
                    dims.dropout,
                )
            } else {
                Mat::filled(xn.local.rows, xn.local.cols, 1.0)
            };
            let mut fd = xn; // consume: xn is not needed past this point
            timed!(self.elementwise, {
                for (o, &m) in fd.local.data.iter_mut().zip(&mask.data) {
                    *o = o.max(0.0) * m;
                }
            });
            // resharded residual (§IV-C4)
            let res = self.ctx.reshard(
                &f,
                fd.layout,
                cb_of(fd.layout.row_axis),
                self.ctx.static_bounds(dims.d_h, fd.layout.col_axis),
            );
            timed!(self.elementwise, fd.local.add_assign(&res.local));
            caches.push(LayerCacheP { f_in: f, h_agg, xc, inv, mask, adj: sub });
            f = fd;
        }

        // output head
        let logits = self.ctx.mm(&f, &self.w_out);
        (logits, caches, sample, f, x_in)
    }

    /// Parallel masked cross-entropy: returns (loss, acc, dlogits).
    fn parallel_loss(
        &mut self,
        logits: &PmmMat,
        y_of: impl Fn(usize) -> u32,
        w_of: impl Fn(usize) -> f32,
    ) -> (f32, f32, PmmMat) {
        let rows = logits.local.rows;
        let cols = logits.local.cols;
        let class_axis = logits.layout.col_axis;
        let row_axis = logits.layout.row_axis;
        let c0 = logits.col_bounds[self.ctx.axis_coord(class_axis)];
        let r0 = logits.row_bounds[self.ctx.axis_coord(row_axis)];

        // row maxima across the class shards
        let local_max: Vec<f32> = (0..rows)
            .map(|r| logits.local.row(r).iter().cloned().fold(f32::NEG_INFINITY, f32::max))
            .collect();
        // loss reductions stay FP32 (§V-B): the argmax gather below ships
        // f32-encoded class indices bf16 rounding would corrupt
        let maxes =
            self.ctx.world.all_gather(self.ctx.rank, class_axis, &local_max, Precision::Fp32);
        let gmax: Vec<f32> = (0..rows)
            .map(|r| maxes.iter().map(|p| p[r]).fold(f32::NEG_INFINITY, f32::max))
            .collect();
        // log-sum-exp
        let mut local_sum: Vec<f32> = (0..rows)
            .map(|r| logits.local.row(r).iter().map(|&v| (v - gmax[r]).exp()).sum())
            .collect();
        self.ctx
            .world
            .all_reduce(self.ctx.rank, class_axis, &mut local_sum, Precision::Fp32);
        let lse: Vec<f32> = (0..rows).map(|r| local_sum[r].ln() + gmax[r]).collect();

        // local argmax with global class ids (for accuracy)
        let local_arg: Vec<f32> = (0..rows)
            .flat_map(|r| {
                let row = logits.local.row(r);
                let (mut bi, mut bv) = (0usize, f32::NEG_INFINITY);
                for (j, &v) in row.iter().enumerate() {
                    if v > bv {
                        bv = v;
                        bi = j;
                    }
                }
                [(c0 + bi) as f32, bv]
            })
            .collect();
        let args =
            self.ctx.world.all_gather(self.ctx.rank, class_axis, &local_arg, Precision::Fp32);

        // loss/acc partial sums + dlogits (fresh buffer, fully overwritten
        // below — no need to copy the logits data)
        let mut dlogits = PmmMat {
            layout: logits.layout,
            row_bounds: logits.row_bounds.clone(),
            col_bounds: logits.col_bounds.clone(),
            local: Mat::zeros(rows, cols),
        };
        let mut sums = vec![0.0f32; 3]; // [loss, correct, denom]
        for r in 0..rows {
            let y = y_of(r0 + r);
            let w = w_of(r0 + r);
            sums[2] += w;
            // global argmax
            let (mut bi, mut bv) = (0usize, f32::NEG_INFINITY);
            for p in &args {
                if p[2 * r + 1] > bv {
                    bv = p[2 * r + 1];
                    bi = p[2 * r] as usize;
                }
            }
            if w != 0.0 {
                if bi == y as usize {
                    sums[1] += w;
                }
                if (y as usize) >= c0 && (y as usize) < c0 + cols {
                    sums[0] += -(logits.local.at(r, y as usize - c0) - lse[r]) * w;
                }
            }
            let drow = &mut dlogits.local.data[r * cols..(r + 1) * cols];
            for j in 0..cols {
                let sm = (logits.local.at(r, j) - lse[r]).exp();
                let onehot = if c0 + j == y as usize { 1.0 } else { 0.0 };
                drow[j] = w * (sm - onehot);
            }
        }
        // loss terms live on one class-shard only -> AR over classes, then
        // over row blocks; denominators likewise
        self.ctx
            .world
            .all_reduce(self.ctx.rank, class_axis, &mut sums[..1], Precision::Fp32);
        let mut row_sums = [sums[0], sums[1], sums[2]];
        self.ctx
            .world
            .all_reduce(self.ctx.rank, row_axis, &mut row_sums, Precision::Fp32);
        let denom = row_sums[2].max(1.0);
        for d in dlogits.local.data.iter_mut() {
            *d /= denom;
        }
        (row_sums[0] / denom, row_sums[1] / denom, dlogits)
    }

    /// One 4D training step: Algorithm 1/2 sampling, 3D PMM forward +
    /// backward, DP gradient all-reduce, rank-local Adam.
    pub fn train_step(&mut self, step: u64, lr: f32) -> PmmStepOutput {
        // fail fast with the recorded origin if a peer died since the
        // last step (otherwise a rank only notices at its next wait)
        self.ctx.check_world();
        let dims = self.dims;
        let (logits, caches, sample, f_last, x_in) = self.forward_sampled(step, true);

        let data = self.data.clone();
        let (loss, acc, dlogits) = self.parallel_loss(
            &logits,
            |i| data.labels[sample[i] as usize],
            |i| if data.split[sample[i] as usize] == 0 { 1.0 } else { 0.0 },
        );

        // ---- backward (§V-D overlapped schedule) ----
        let overlap = self.overlap;
        let n = self.data.n;
        let cb = |ax: Axis, s: &[u32]| -> Arc<Vec<usize>> {
            Arc::new(compact_bounds(s, n, self.ctx.axis_size(ax)))
        };

        // Gradient pipeline: parameter-gradient contraction all-reduces
        // are *issued* the moment the local partial product exists and
        // drained in a fixed order (w_out, then per layer g_l, w_l,
        // finally w_in); every landed bucket immediately becomes its
        // per-layer DP all-reduce.  Slots are in optimizer order:
        // 0 = w_in, 1+2l = w_l, 2+2l = g_l, last = w_out.  With overlap
        // off, each handle is resolved at its issue point instead — the
        // blocking baseline; both schedules are bitwise identical because
        // the collective engine reduces in group-index order.
        let n_slots = 2 * dims.layers + 2;
        let mut grads: Vec<Option<Vec<f32>>> = (0..n_slots).map(|_| None).collect();
        let mut tp_queue: VecDeque<(usize, PendingTpGrad)> = VecDeque::new();
        let mut dp_queue: VecDeque<(usize, PendingVec)> = VecDeque::new();

        // output head (Eqs. 13-14): d_wout is needed only by the optimizer,
        // so its contraction all-reduce is issued, not awaited
        stage_tp_grad(
            &self.ctx,
            overlap,
            n_slots - 1,
            PendingTpGrad::Mat(self.ctx.mm_ta_issue(&f_last, &dlogits)),
            &mut tp_queue,
            &mut dp_queue,
            &mut grads,
            &mut self.timers,
        );
        let mut df = self.ctx.mm_tb(&dlogits, &self.w_out);

        for l in (0..dims.layers).rev() {
            let lc = &caches[l];
            let fl = self.f_layouts[l];
            let (t_ax, r_ax) = (fl.third(), fl.row_axis);

            // element-wise backward (dropout, relu, rmsnorm w/ AR'd dot);
            // dxc is fully overwritten below, and the reduction scratch
            // (dots, dxn) is reused across layers and steps
            let rows = df.local.rows;
            let cols = df.local.cols;
            let gslice = &self.g[l];
            let mut dxc = PmmMat {
                layout: df.layout,
                row_bounds: df.row_bounds.clone(),
                col_bounds: df.col_bounds.clone(),
                local: Mat::zeros(rows, cols),
            };
            let mut dg = vec![0.0f32; cols];
            self.scratch_dots.clear();
            self.scratch_dots.resize(rows, 0.0);
            self.scratch_dxn.clear();
            self.scratch_dxn.resize(rows * cols, 0.0);
            let dots = &mut self.scratch_dots;
            let dxn_all = &mut self.scratch_dxn;
            timed!(self.elementwise, {
                for r in 0..rows {
                    let inv = lc.inv[r];
                    for j in 0..cols {
                        let xc = lc.xc.local.at(r, j);
                        let xn = xc * inv;
                        let y0 = xn * gslice[j];
                        let dy0 = if y0 > 0.0 {
                            df.local.at(r, j) * lc.mask.at(r, j)
                        } else {
                            0.0
                        };
                        dg[j] += dy0 * xn;
                        let dxn = dy0 * gslice[j];
                        dxn_all[r * cols + j] = dxn;
                        dots[r] += dxn * xc;
                    }
                }
            });
            // the RMSNorm dot is a full-row reduction: AR over cols (FP32)
            // — a true dependency of dxc, so it stays blocking
            let t_ar = std::time::Instant::now();
            self.ctx.world.all_reduce(
                self.ctx.rank,
                df.layout.col_axis,
                dots,
                Precision::Fp32,
            );
            self.timers.tp_comm += t_ar.elapsed().as_secs_f64();
            // dg is replicated over C_l and needed only by the optimizer:
            // its row-block (T_l) sum is issued, not awaited (§V-D)
            stage_tp_grad(
                &self.ctx,
                overlap,
                2 + 2 * l,
                PendingTpGrad::Vec(self.ctx.issue_vec(df.layout.row_axis, dg, Precision::Fp32)),
                &mut tp_queue,
                &mut dp_queue,
                &mut grads,
                &mut self.timers,
            );
            timed!(self.elementwise, {
                for r in 0..rows {
                    let inv = lc.inv[r];
                    let dot = dots[r] / dims.d_h as f32;
                    for j in 0..cols {
                        let xc = lc.xc.local.at(r, j);
                        dxc.local.data[r * cols + j] =
                            inv * (dxn_all[r * cols + j] - xc * dot * inv * inv);
                    }
                }
            });

            // GEMM backward (Eqs. 15-16): dW_l is optimizer-only, so its
            // contraction all-reduce is issued; dH is the next true
            // dependency and stays blocking
            stage_tp_grad(
                &self.ctx,
                overlap,
                1 + 2 * l,
                PendingTpGrad::Mat(self.ctx.mm_ta_issue(&lc.h_agg, &dxc)),
                &mut tp_queue,
                &mut dp_queue,
                &mut grads,
                &mut self.timers,
            );
            let dh_agg = self.ctx.mm_tb(&dxc, &self.w[l]);

            // SpMM backward (Eq. 17)
            let df_conv =
                self.ctx.spmm_ta(&lc.adj.adj, &cb(r_ax, &sample), r_ax, t_ax, &dh_agg);

            // residual skip: df resharded back to the layer-input layout
            let df_skip = self.ctx.reshard(
                &df,
                lc.f_in.layout,
                cb(lc.f_in.layout.row_axis, &sample),
                self.ctx.static_bounds(dims.d_h, lc.f_in.layout.col_axis),
            );
            df = df_conv;
            timed!(self.elementwise, df.local.add_assign(&df_skip.local));

            if overlap {
                // layer boundary: advance chunk reductions and turn landed
                // contraction ARs into their per-layer DP buckets
                self.ctx.progress();
                drain_tp_queue(&self.ctx, &mut tp_queue, &mut dp_queue, &mut grads, false, false, &mut self.timers);
            }
        }

        // input projection backward (Eq. 18); the feature shard gathered in
        // the forward pass is reused instead of re-gathered
        stage_tp_grad(
            &self.ctx,
            overlap,
            0,
            PendingTpGrad::Mat(self.ctx.mm_ta_issue(&x_in, &df)),
            &mut tp_queue,
            &mut dp_queue,
            &mut grads,
            &mut self.timers,
        );

        // resolve the remaining contraction ARs (fixed order) and wait out
        // every DP gradient bucket; the division by Gd happens after the
        // reduction exactly as on the blocking path
        drain_tp_queue(&self.ctx, &mut tp_queue, &mut dp_queue, &mut grads, true, !overlap, &mut self.timers);
        if self.ctx.grid.gd > 1 {
            let gd = self.ctx.grid.gd as f32;
            let t0 = std::time::Instant::now();
            while let Some((slot, p)) = dp_queue.pop_front() {
                let mut data = p.wait();
                for v in data.iter_mut() {
                    *v /= gd;
                }
                grads[slot] = Some(data);
            }
            self.timers.dp_comm += t0.elapsed().as_secs_f64();
        }

        // ---- Adam (rank-local, shards stay in sync) ----
        timed!(self.other, {
            self.t += 1.0;
            let t = self.t;
            let mut idx = 0;
            let apply = |p: &mut [f32], g: &[f32], m: &mut Vec<f32>, v: &mut Vec<f32>| {
                let b1t = 1.0 - ADAM_B1.powf(t);
                let b2t = 1.0 - ADAM_B2.powf(t);
                for k in 0..p.len() {
                    m[k] = ADAM_B1 * m[k] + (1.0 - ADAM_B1) * g[k];
                    v[k] = ADAM_B2 * v[k] + (1.0 - ADAM_B2) * g[k] * g[k];
                    p[k] -= lr * (m[k] / b1t) / ((v[k] / b2t).sqrt() + ADAM_EPS);
                }
            };
            let (m, v) = (&mut self.adam_m, &mut self.adam_v);
            let g0 = grads[0].take().expect("w_in gradient resolved");
            apply(&mut self.w_in.local.data, &g0, &mut m[idx], &mut v[idx]);
            idx += 1;
            for l in 0..dims.layers {
                let gw = grads[1 + 2 * l].take().expect("w_l gradient resolved");
                apply(&mut self.w[l].local.data, &gw, &mut m[idx], &mut v[idx]);
                idx += 1;
                let gg = grads[2 + 2 * l].take().expect("g_l gradient resolved");
                apply(&mut self.g[l], &gg, &mut m[idx], &mut v[idx]);
                idx += 1;
            }
            let gout = grads[n_slots - 1].take().expect("w_out gradient resolved");
            apply(&mut self.w_out.local.data, &gout, &mut m[idx], &mut v[idx]);
        });

        // fold the context's per-op timings into the step accumulators
        let ct = self.ctx.drain_timers();
        self.timers.add(&ct);

        // recycle the step's per-layer subgraph buffers (and the sample
        // that was moved out of slot 0) so the prefetcher's next
        // Algorithm-2 build is allocation-free
        self.prefetcher.recycle(caches.into_iter().map(|c| c.adj).collect(), sample);

        PmmStepOutput { loss, acc }
    }

    /// Distributed full-graph evaluation (Table II): a single 3D-PMM
    /// forward over the *entire* (sparse) graph, dropout off.
    /// Returns (val_acc, test_acc).
    pub fn eval_full_graph(&mut self) -> (f32, f32) {
        let dims = self.dims;
        let n = self.data.n;
        let ctx = &self.ctx;
        let cb = |ax: Axis| -> Arc<Vec<usize>> { ctx.static_bounds(n, ax) };

        // features on (X, Z)
        let cbx = cb(Axis::X);
        let all: Vec<u32> = {
            let (r0, r1) = ctx.my_block(&cbx, Axis::X);
            (r0 as u32..r1 as u32).collect()
        };
        let d_in = dims.d_in;
        let col_b = ctx.static_bounds(d_in, Axis::Z);
        let (c0, c1) = ctx.my_block(&col_b, Axis::Z);
        let mut local = Mat::zeros(all.len(), c1 - c0);
        for (k, &v) in all.iter().enumerate() {
            local.data[k * (c1 - c0)..(k + 1) * (c1 - c0)].copy_from_slice(
                &self.data.features.data[v as usize * d_in + c0..v as usize * d_in + c1],
            );
        }
        let x_in = PmmMat {
            layout: Layout::new(Axis::X, Axis::Z),
            row_bounds: cbx,
            col_bounds: col_b,
            local,
        };
        let mut f = ctx.mm(&x_in, &self.w_in);

        for l in 0..dims.layers {
            let fl = self.f_layouts[l];
            let (t_ax, r_ax) = (fl.third(), fl.row_axis);
            let rb = block_bounds(n, ctx.axis_size(t_ax));
            let cbv = block_bounds(n, ctx.axis_size(r_ax));
            let (r0, r1) = (rb[ctx.axis_coord(t_ax)], rb[ctx.axis_coord(t_ax) + 1]);
            let (cc0, cc1) = (cbv[ctx.axis_coord(r_ax)], cbv[ctx.axis_coord(r_ax) + 1]);
            let shard = extract_shard(&self.data.adj, r0, r1, cc0, cc1);
            let h_agg = ctx.spmm(&shard.csr, &cb(t_ax), t_ax, r_ax, &f);
            let xc = ctx.mm(&h_agg, &self.w[l]);
            let (mut xn, _) = ctx.rmsnorm_slice(&xc, &self.g[l]);
            for v in xn.local.data.iter_mut() {
                *v = v.max(0.0);
            }
            let res = ctx.reshard(
                &f,
                xn.layout,
                cb(xn.layout.row_axis),
                ctx.static_bounds(dims.d_h, xn.layout.col_axis),
            );
            xn.local.add_assign(&res.local);
            f = xn;
        }
        let logits = ctx.mm(&f, &self.w_out);

        // accuracy over val/test splits via the parallel loss machinery
        let data = self.data.clone();
        let (_l1, val_acc, _d1) =
            self.parallel_loss(&logits, |i| data.labels[i], |i| {
                if data.split[i] == 1 {
                    1.0
                } else {
                    0.0
                }
            });
        let (_l2, test_acc, _d2) =
            self.parallel_loss(&logits, |i| data.labels[i], |i| {
                if data.split[i] == 2 {
                    1.0
                } else {
                    0.0
                }
            });
        (val_acc, test_acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommWorld;
    use crate::graph::datasets;
    use crate::grid::Grid4D;
    use crate::model;
    use crate::sampling::induce_rescaled;

    fn tiny_dims() -> GcnDims {
        GcnDims { d_in: 16, d_h: 16, d_out: 4, layers: 2, dropout: 0.0, weight_decay: 0.0 }
    }

    /// Run k engine steps on every rank of `grid`; returns per-rank
    /// (losses, accs, gathered params).
    fn run_engine(
        grid: Grid4D,
        dims: GcnDims,
        batch: usize,
        steps: u64,
        lr: f32,
        prec: Precision,
    ) -> Vec<(Vec<f32>, Vec<f32>, Vec<Mat>)> {
        let data = Arc::new(datasets::load("tiny").unwrap());
        let world = Arc::new(CommWorld::new(grid));
        let mut hs = vec![];
        for r in 0..grid.world_size() {
            let w = world.clone();
            let d = data.clone();
            hs.push(std::thread::spawn(move || {
                let ctx = super::super::PmmCtx::new(grid, r, &w, prec);
                let mut eng = PmmGcn::new(ctx, dims, batch, d, 42);
                let mut losses = vec![];
                let mut accs = vec![];
                for s in 0..steps {
                    let out = eng.train_step(s, lr);
                    losses.push(out.loss);
                    accs.push(out.acc);
                }
                let params = eng.gather_params();
                (losses, accs, params)
            }));
        }
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    }

    /// Reference single-process trajectory with the same sampling stream.
    fn run_reference(dims: GcnDims, batch: usize, steps: u64, lr: f32) -> (Vec<f32>, Vec<Mat>) {
        let data = datasets::load("tiny").unwrap();
        let group_seed = crate::util::rng::splitmix64(42 ^ 0xD0);
        let sampler = UniformVertexSampler::new(data.n, batch, group_seed);
        let mut params = model::init_params(&dims, 42);
        let mut opt = model::AdamState::new(&dims);
        let mut losses = vec![];
        for s in 0..steps {
            let sample = sampler.sample(s);
            let mb = induce_rescaled(&data.adj, &sample, sampler.inclusion_prob());
            let mut x = Mat::zeros(batch, dims.d_in);
            for (i, &v) in sample.iter().enumerate() {
                x.data[i * dims.d_in..(i + 1) * dims.d_in].copy_from_slice(
                    &data.features.data[v as usize * dims.d_in..(v as usize + 1) * dims.d_in],
                );
            }
            let y: Vec<u32> = sample.iter().map(|&v| data.labels[v as usize]).collect();
            let w: Vec<f32> = sample
                .iter()
                .map(|&v| if data.split[v as usize] == 0 { 1.0 } else { 0.0 })
                .collect();
            let masks = vec![Mat::filled(batch, dims.d_h, 1.0); dims.layers];
            let (l, _a) = model::train_step(
                &dims, &mut params, &mut opt, &mb.adj, &mb.adj_t, &x, &y, &w, &masks, lr,
            );
            losses.push(l);
        }
        (losses, params)
    }

    fn assert_params_close(got: &[Mat], want: &[Mat], tol: f32) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let d = g.max_abs_diff(w);
            assert!(d < tol, "param {i} max diff {d}");
        }
    }

    #[test]
    fn engine_matches_reference_on_1x1x1() {
        let dims = tiny_dims();
        let outs = run_engine(Grid4D::new(1, 1, 1, 1), dims, 64, 4, 5e-3, Precision::Fp32);
        let (ref_losses, ref_params) = run_reference(dims, 64, 4, 5e-3);
        for (l_got, l_want) in outs[0].0.iter().zip(&ref_losses) {
            assert!((l_got - l_want).abs() < 1e-4, "{l_got} vs {l_want}");
        }
        assert_params_close(&outs[0].2, &ref_params, 1e-4);
    }

    #[test]
    fn engine_matches_reference_on_2x2x2() {
        let dims = tiny_dims();
        let outs = run_engine(Grid4D::new(1, 2, 2, 2), dims, 64, 3, 5e-3, Precision::Fp32);
        let (ref_losses, ref_params) = run_reference(dims, 64, 3, 5e-3);
        for out in &outs {
            for (l_got, l_want) in out.0.iter().zip(&ref_losses) {
                assert!((l_got - l_want).abs() < 5e-4, "{l_got} vs {l_want}");
            }
            assert_params_close(&out.2, &ref_params, 5e-4);
        }
    }

    #[test]
    fn engine_matches_reference_on_skewed_grids() {
        let dims = tiny_dims();
        for grid in [Grid4D::new(1, 4, 1, 1), Grid4D::new(1, 1, 2, 2), Grid4D::new(1, 2, 1, 2)] {
            let outs = run_engine(grid, dims, 48, 2, 5e-3, Precision::Fp32);
            let (ref_losses, _) = run_reference(dims, 48, 2, 5e-3);
            for out in &outs {
                for (l_got, l_want) in out.0.iter().zip(&ref_losses) {
                    assert!(
                        (l_got - l_want).abs() < 5e-4,
                        "grid {grid:?}: {l_got} vs {l_want}"
                    );
                }
            }
        }
    }

    #[test]
    fn dp_groups_draw_distinct_batches_and_stay_in_sync() {
        let dims = tiny_dims();
        let outs = run_engine(Grid4D::new(2, 1, 1, 1), dims, 48, 3, 5e-3, Precision::Fp32);
        // different groups see different batches -> different losses
        assert_ne!(outs[0].0, outs[1].0);
        // but DP-synchronized params must be identical
        for (g0, g1) in outs[0].2.iter().zip(&outs[1].2) {
            assert!(g0.max_abs_diff(g1) < 1e-6);
        }
    }

    #[test]
    fn bf16_collectives_stay_close_to_fp32() {
        let dims = tiny_dims();
        let f32_out = run_engine(Grid4D::new(1, 2, 1, 1), dims, 48, 3, 5e-3, Precision::Fp32);
        let bf_out = run_engine(Grid4D::new(1, 2, 1, 1), dims, 48, 3, 5e-3, Precision::Bf16);
        for (a, b) in f32_out[0].0.iter().zip(&bf_out[0].0) {
            assert!((a - b).abs() < 0.05, "bf16 loss {b} vs fp32 {a}");
        }
    }

    #[test]
    fn dropout_training_still_converges() {
        let dims = GcnDims { dropout: 0.3, ..tiny_dims() };
        let outs = run_engine(Grid4D::new(1, 2, 2, 1), dims, 64, 12, 5e-3, Precision::Fp32);
        let losses = &outs[0].0;
        assert!(
            losses[9..].iter().sum::<f32>() / 3.0 < losses[..3].iter().sum::<f32>() / 3.0,
            "{losses:?}"
        );
    }

    #[test]
    fn eval_full_graph_matches_reference_eval() {
        let dims = tiny_dims();
        let data = Arc::new(datasets::load("tiny").unwrap());
        // reference eval accuracy with the same (seed 42) init params
        let params = model::init_params(&dims, 42);
        let (logits, _) = model::forward(&dims, &params, &data.adj, &data.features, None);
        let y: Vec<u32> = data.labels.clone();
        let wtest: Vec<f32> = data
            .split
            .iter()
            .map(|&s| if s == 2 { 1.0 } else { 0.0 })
            .collect();
        let (_, want_acc, _) = model::loss_and_grad(&logits, &y, &wtest);

        for grid in [Grid4D::new(1, 1, 1, 1), Grid4D::new(1, 2, 2, 2)] {
            let world = Arc::new(CommWorld::new(grid));
            let mut hs = vec![];
            for r in 0..grid.world_size() {
                let w = world.clone();
                let d = data.clone();
                hs.push(std::thread::spawn(move || {
                    let ctx = super::super::PmmCtx::new(grid, r, &w, Precision::Fp32);
                    let mut eng = PmmGcn::new(ctx, dims, 64, d, 42);
                    eng.eval_full_graph()
                }));
            }
            for h in hs {
                let (_val, test) = h.join().unwrap();
                assert!(
                    (test - want_acc).abs() < 1e-4,
                    "grid {grid:?}: {test} vs {want_acc}"
                );
            }
        }
    }

    #[test]
    fn export_restore_resumes_bitwise() {
        // run 4 steps straight vs run 2, export, restore into a FRESH
        // engine (new world, new prefetcher), run steps 2..4 — dropout on,
        // so the stateless (seed, step) mask derivation is exercised too
        let dims = GcnDims { dropout: 0.3, ..tiny_dims() };
        let data = Arc::new(datasets::load("tiny").unwrap());
        let grid = Grid4D::new(1, 1, 1, 1);

        let world_a = Arc::new(CommWorld::new(grid));
        let ctx_a = super::super::PmmCtx::new(grid, 0, &world_a, Precision::Fp32);
        let mut a = PmmGcn::new(ctx_a, dims, 48, data.clone(), 42);
        let straight: Vec<u32> =
            (0..4).map(|s| a.train_step(s, 5e-3).loss.to_bits()).collect();

        let world_b = Arc::new(CommWorld::new(grid));
        let ctx_b = super::super::PmmCtx::new(grid, 0, &world_b, Precision::Fp32);
        let mut b = PmmGcn::new(ctx_b, dims, 48, data.clone(), 42);
        let mut resumed: Vec<u32> =
            (0..2).map(|s| b.train_step(s, 5e-3).loss.to_bits()).collect();
        let (tensors, m, v, t) = b.export_state();
        drop(b);

        let world_c = Arc::new(CommWorld::new(grid));
        let ctx_c = super::super::PmmCtx::new(grid, 0, &world_c, Precision::Fp32);
        let mut c = PmmGcn::new(ctx_c, dims, 48, data, 42);
        c.restore_state(&tensors, &m, &v, t).unwrap();
        resumed.extend((2..4).map(|s| c.train_step(s, 5e-3).loss.to_bits()));

        assert_eq!(straight, resumed, "resume must replay the exact trajectory");
    }

    #[test]
    fn restore_state_rejects_shape_mismatch_untouched() {
        let dims = tiny_dims();
        let data = Arc::new(datasets::load("tiny").unwrap());
        let grid = Grid4D::new(1, 1, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let ctx = super::super::PmmCtx::new(grid, 0, &world, Precision::Fp32);
        let mut eng = PmmGcn::new(ctx, dims, 48, data, 42);
        let (mut tensors, m, v, t) = eng.export_state();
        tensors[1].pop(); // corrupt one shard length
        let before = eng.export_state();
        let err = eng.restore_state(&tensors, &m, &v, t).unwrap_err().to_string();
        assert!(err.contains("tensor 1"), "{err}");
        let after = eng.export_state();
        assert_eq!(before.0, after.0, "failed restore must not mutate the engine");
    }

    #[test]
    fn timers_accumulate_all_phases() {
        let dims = tiny_dims();
        let data = Arc::new(datasets::load("tiny").unwrap());
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let mut hs = vec![];
        for r in 0..2 {
            let w = world.clone();
            let d = data.clone();
            hs.push(std::thread::spawn(move || {
                let ctx = super::super::PmmCtx::new(grid, r, &w, Precision::Fp32);
                let mut eng = PmmGcn::new(ctx, dims, 48, d, 7);
                eng.train_step(0, 1e-3);
                eng.timers
            }));
        }
        for h in hs {
            let t = h.join().unwrap();
            assert!(t.sampling > 0.0);
            assert!(t.gemm > 0.0);
            assert!(t.spmm > 0.0);
            assert!(t.elementwise > 0.0);
            assert!(t.tp_comm > 0.0);
            assert!(t.total() > 0.0);
        }
    }
}
