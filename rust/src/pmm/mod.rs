//! 3D parallel matrix multiplication engine (paper §IV-C).
//!
//! Within one data-parallel group the `Gx x Gy x Gz` ranks hold 2D shards of
//! every matrix, identified by a `Layout` = (row axis, column axis); the
//! third axis is the replication/contraction axis.  One PMM matmul computes
//! the local partial product and all-reduces it over the contraction axis:
//!
//! ```text
//!   mm   : A(r,k) @ B(k,c) -> C(r,c)   all-reduce over k       (Eqs. 27-28)
//!   mm_ta: A(k,r)^T @ B(k,c) -> C(r,c) all-reduce over k       (Eqs. 13,15,17,18)
//!   mm_tb: A(r,k) @ B(c,k)^T -> C(r,c) all-reduce over k       (Eqs. 14,16,19)
//! ```
//!
//! **Layer rotation** (§IV-C3): features rotate (X,Y) -> (Z,X) -> (Y,Z) with
//! period 3; layer `l`'s adjacency shard lives on `(third_l, row_l)` and its
//! weight shard on `(col_l, row_l)`, so every local multiplication is
//! layout-aligned with zero extra communication.  Residual adds reshard the
//! skip tensor (two line all-gathers + slice), as in §IV-C4.
//!
//! Row blocks over the compact mini-batch `[0,B)` are *step-dependent*: they
//! are induced by intersecting the sorted sample S with the static vertex
//! ranges (Fig. 3), so every rank derives identical bounds with no
//! communication.  RMSNorm's sum-of-squares is all-reduced over the column
//! axis (Eq. 29) in FP32 even when BF16 collectives are enabled (§V-B).
//!
//! **§V-D overlap:** `mm_ta_issue` / `issue_vec` / `issue_dp` stage a
//! contraction (or gradient-bucket) all-reduce into the nonblocking chunked
//! collective engine and return a [`PendingMat`] / [`PendingVec`] handle;
//! the engine's backward pass resolves them only at the optimizer, hiding
//! the reductions behind the remaining backward kernels.

use std::sync::Arc;

use crate::comm::{CommWorld, Precision};
use crate::graph::{block_bounds, Csr};
use crate::grid::{Axis, Coord, Grid4D};
use crate::model::RMS_EPS;
use crate::tensor::Mat;
use crate::util::rng::{splitmix64, Rng};

/// Shard layout: rows split across `row_axis`, cols across `col_axis`,
/// replicated along the remaining axis (also the matmul contraction axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layout {
    /// Axis the rows are split across.
    pub row_axis: Axis,
    /// Axis the columns are split across.
    pub col_axis: Axis,
}

impl Layout {
    /// Layout with distinct row/column axes.
    pub fn new(row_axis: Axis, col_axis: Axis) -> Layout {
        assert_ne!(row_axis, col_axis);
        Layout { row_axis, col_axis }
    }

    /// The replication / contraction axis.
    pub fn third(&self) -> Axis {
        third(self.row_axis, self.col_axis)
    }
}

/// The remaining tensor-parallel axis given two distinct ones.
pub fn third(a: Axis, b: Axis) -> Axis {
    match (a, b) {
        (Axis::X, Axis::Y) | (Axis::Y, Axis::X) => Axis::Z,
        (Axis::X, Axis::Z) | (Axis::Z, Axis::X) => Axis::Y,
        (Axis::Y, Axis::Z) | (Axis::Z, Axis::Y) => Axis::X,
        _ => panic!("third() of {a:?},{b:?}"),
    }
}

/// Feature layouts per position: F0 = (X,Y), F_{l+1} = (third_l, R_l).
pub fn feature_layouts(layers: usize) -> Vec<Layout> {
    let mut v = vec![Layout::new(Axis::X, Axis::Y)];
    for _ in 0..layers {
        let prev = *v.last().unwrap();
        v.push(Layout::new(prev.third(), prev.row_axis));
    }
    v
}

/// A sharded dense matrix: this rank's local block plus the global block
/// boundaries along both axes.
#[derive(Clone, Debug)]
pub struct PmmMat {
    /// Which axes the rows/columns are split across.
    pub layout: Layout,
    /// Global row-block boundaries along `layout.row_axis`.
    pub row_bounds: Arc<Vec<usize>>,
    /// Global column-block boundaries along `layout.col_axis`.
    pub col_bounds: Arc<Vec<usize>>,
    /// This rank's local block.
    pub local: Mat,
}

impl PmmMat {
    /// Global row count (last row boundary).
    pub fn global_rows(&self) -> usize {
        *self.row_bounds.last().unwrap()
    }

    /// Global column count (last column boundary).
    pub fn global_cols(&self) -> usize {
        *self.col_bounds.last().unwrap()
    }
}

/// A sharded matrix whose contraction all-reduce has been issued but not
/// yet awaited (§V-D overlap): the local block holds the un-reduced
/// partial product until [`PendingMat::wait`] resolves it in place.
#[must_use = "a pending PMM result must be awaited"]
pub struct PendingMat<'w> {
    op: crate::comm::PendingOp<'w>,
    mat: PmmMat,
}

impl PendingMat<'_> {
    /// Nonblocking completion check (drives chunk reductions).
    pub fn try_ready(&self) -> bool {
        self.op.try_ready()
    }

    /// Block until the contraction all-reduce lands; returns the reduced
    /// matrix.
    pub fn wait(self) -> PmmMat {
        let PendingMat { op, mut mat } = self;
        op.wait_into(&mut mat.local.data);
        mat
    }
}

/// A flat vector whose all-reduce has been issued but not yet awaited
/// (§V-D): used for RMSNorm-scale gradients and DP gradient buckets.
#[must_use = "a pending vector reduction must be awaited"]
pub struct PendingVec<'w> {
    op: crate::comm::PendingOp<'w>,
    data: Vec<f32>,
}

impl PendingVec<'_> {
    /// Nonblocking completion check (drives chunk reductions).
    pub fn try_ready(&self) -> bool {
        self.op.try_ready()
    }

    /// Block until the reduction lands; returns the reduced vector.
    pub fn wait(self) -> Vec<f32> {
        let PendingVec { op, mut data } = self;
        op.wait_into(&mut data);
        data
    }
}

/// Per-rank execution context.
pub struct PmmCtx<'a> {
    /// The 4D grid this rank belongs to.
    pub grid: Grid4D,
    /// This rank's id.
    pub rank: usize,
    /// This rank's (d, x, y, z) coordinates.
    pub coord: Coord,
    /// Shared-memory collectives of the grid.
    pub world: &'a CommWorld,
    /// precision for the PMM matmul all-reduces (§V-B: BF16 optional)
    pub tp_precision: Precision,
    /// per-phase wall-clock accumulators; drained by the engine per step
    pub timers: std::cell::RefCell<PmmTimers>,
}

impl<'a> PmmCtx<'a> {
    /// Context for `rank` of `grid`, with `tp` as the matmul all-reduce
    /// precision (§V-B).
    pub fn new(grid: Grid4D, rank: usize, world: &'a CommWorld, tp: Precision) -> Self {
        PmmCtx {
            grid,
            rank,
            coord: grid.coord(rank),
            world,
            tp_precision: tp,
            timers: std::cell::RefCell::new(PmmTimers::default()),
        }
    }

    /// Take and reset the accumulated phase timers.
    pub fn drain_timers(&self) -> PmmTimers {
        std::mem::take(&mut self.timers.borrow_mut())
    }

    /// Die with the recorded failure origin if any of this rank's groups
    /// was poisoned.  The engine calls this at every step boundary so a
    /// rank whose next collective is several phases away still learns of
    /// a dead peer promptly — essential over the socket transports, where
    /// a poisoned world otherwise only surfaces at the next wire
    /// round-trip.
    pub fn check_world(&self) {
        if let Some(err) = self.world.poison_of(self.rank) {
            std::panic::panic_any(err);
        }
    }

    fn time<T>(&self, f: impl FnOnce() -> T, pick: impl FnOnce(&mut PmmTimers) -> &mut f64) -> T {
        let t0 = std::time::Instant::now();
        let r = f();
        *pick(&mut self.timers.borrow_mut()) += t0.elapsed().as_secs_f64();
        r
    }

    /// This rank's coordinate along `a`.
    pub fn axis_coord(&self, a: Axis) -> usize {
        match a {
            Axis::X => self.coord.x,
            Axis::Y => self.coord.y,
            Axis::Z => self.coord.z,
            Axis::Dp => self.coord.d,
        }
    }

    /// Extent of the grid along `a`.
    pub fn axis_size(&self, a: Axis) -> usize {
        self.grid.axis_size(a)
    }

    /// This rank's block range along `axis` given the bounds vector.
    pub fn my_block<'b>(&self, bounds: &'b [usize], axis: Axis) -> (usize, usize) {
        let i = self.axis_coord(axis);
        (bounds[i], bounds[i + 1])
    }

    /// Equal-split bounds of a static dimension along `axis`.
    pub fn static_bounds(&self, n: usize, axis: Axis) -> Arc<Vec<usize>> {
        Arc::new(block_bounds(n, self.axis_size(axis)))
    }

    /// Shard a replicated global matrix into this rank's block.
    pub fn shard_from_global(&self, global: &Mat, layout: Layout) -> PmmMat {
        let rb = self.static_bounds(global.rows, layout.row_axis);
        let cb = self.static_bounds(global.cols, layout.col_axis);
        let (r0, r1) = self.my_block(&rb, layout.row_axis);
        let (c0, c1) = self.my_block(&cb, layout.col_axis);
        PmmMat { layout, row_bounds: rb, col_bounds: cb, local: global.slice(r0, r1, c0, c1) }
    }

    fn all_reduce(&self, axis: Axis, data: &mut [f32], prec: Precision) {
        let dp = axis == Axis::Dp;
        self.time(
            || self.world.all_reduce(self.rank, axis, data, prec),
            |t| if dp { &mut t.dp_comm } else { &mut t.tp_comm },
        );
    }

    /// mm: A(r,k) @ B(k,c) -> C(r,c), all-reduce over k.
    ///
    /// Rank-local kernels run single-threaded on purpose: every grid rank
    /// is already its own thread (it models one device), so nesting the
    /// parallel kernels here would oversubscribe the host and charge spawn
    /// overhead to the per-phase timers.
    pub fn mm(&self, a: &PmmMat, b: &PmmMat) -> PmmMat {
        let k_axis = a.layout.col_axis;
        assert_eq!(k_axis, b.layout.row_axis, "contraction axes must match");
        let out_layout = Layout::new(a.layout.row_axis, b.layout.col_axis);
        debug_assert_eq!(a.col_bounds.as_slice(), b.row_bounds.as_slice());
        let mut c = self.time(
            || {
                let mut c = Mat::zeros(a.local.rows, b.local.cols);
                // accumulate over the zeroed buffer: identical result, one
                // memset instead of two inside the timed section
                crate::tensor::matmul_into_threads(&a.local, &b.local, &mut c, true, 1);
                c
            },
            |t| &mut t.gemm,
        );
        self.all_reduce(k_axis, &mut c.data, self.tp_precision);
        PmmMat {
            layout: out_layout,
            row_bounds: a.row_bounds.clone(),
            col_bounds: b.col_bounds.clone(),
            local: c,
        }
    }

    /// Local kernel of `mm_ta`: the un-reduced partial product plus the
    /// contraction axis and output layout (shared by the blocking and the
    /// nonblocking §V-D entry points).
    fn mm_ta_local(&self, a: &PmmMat, b: &PmmMat) -> (Axis, Layout, Mat) {
        let k_axis = a.layout.row_axis;
        assert_eq!(k_axis, b.layout.row_axis);
        let out_layout = Layout::new(a.layout.col_axis, b.layout.col_axis);
        debug_assert_eq!(a.row_bounds.as_slice(), b.row_bounds.as_slice());
        let c = self.time(
            || {
                let mut c = Mat::zeros(a.local.cols, b.local.cols);
                crate::tensor::t_matmul_into_threads(&a.local, &b.local, &mut c, 1);
                c
            },
            |t| &mut t.gemm,
        );
        (k_axis, out_layout, c)
    }

    /// mm_ta: A(k,r)^T @ B(k,c) -> C(r,c), all-reduce over k.
    pub fn mm_ta(&self, a: &PmmMat, b: &PmmMat) -> PmmMat {
        let (k_axis, out_layout, mut c) = self.mm_ta_local(a, b);
        self.all_reduce(k_axis, &mut c.data, self.tp_precision);
        PmmMat {
            layout: out_layout,
            row_bounds: a.col_bounds.clone(),
            col_bounds: b.col_bounds.clone(),
            local: c,
        }
    }

    /// As `mm_ta` but the contraction all-reduce is only *issued* (§V-D):
    /// the local partial product is staged into the chunked collective
    /// engine and the caller keeps computing until [`PendingMat::wait`].
    pub fn mm_ta_issue(&self, a: &PmmMat, b: &PmmMat) -> PendingMat<'a> {
        let (k_axis, out_layout, c) = self.mm_ta_local(a, b);
        let world: &'a CommWorld = self.world;
        let op = world.issue_all_reduce(self.rank, k_axis, &c.data, self.tp_precision);
        PendingMat {
            op,
            mat: PmmMat {
                layout: out_layout,
                row_bounds: a.col_bounds.clone(),
                col_bounds: b.col_bounds.clone(),
                local: c,
            },
        }
    }

    /// Issue an all-reduce of an owned flat vector over `axis` (§V-D);
    /// resolve via [`PendingVec::wait`].
    pub fn issue_vec(&self, axis: Axis, data: Vec<f32>, prec: Precision) -> PendingVec<'a> {
        let world: &'a CommWorld = self.world;
        let op = world.issue_all_reduce(self.rank, axis, &data, prec);
        PendingVec { op, data }
    }

    /// Issue a data-parallel gradient-bucket all-reduce (§V-D per-layer DP
    /// buckets); FP32 like the blocking DP path.
    pub fn issue_dp(&self, data: Vec<f32>) -> PendingVec<'a> {
        self.issue_vec(Axis::Dp, data, Precision::Fp32)
    }

    /// Drive pending chunk reductions for this rank (cheap, nonblocking).
    pub fn progress(&self) -> bool {
        self.world.progress(self.rank)
    }

    /// mm_tb: A(r,k) @ B(c,k)^T -> C(r,c), all-reduce over k.
    pub fn mm_tb(&self, a: &PmmMat, b: &PmmMat) -> PmmMat {
        let k_axis = a.layout.col_axis;
        assert_eq!(k_axis, b.layout.col_axis);
        let out_layout = Layout::new(a.layout.row_axis, b.layout.row_axis);
        debug_assert_eq!(a.col_bounds.as_slice(), b.col_bounds.as_slice());
        let mut c = self.time(
            || {
                let mut c = Mat::zeros(a.local.rows, b.local.rows);
                crate::tensor::matmul_t_into_threads(&a.local, &b.local, &mut c, 1);
                c
            },
            |t| &mut t.gemm,
        );
        self.all_reduce(k_axis, &mut c.data, self.tp_precision);
        PmmMat {
            layout: out_layout,
            row_bounds: a.row_bounds.clone(),
            col_bounds: b.row_bounds.clone(),
            local: c,
        }
    }

    /// Sparse mm: A_csr(r,k) @ B(k,c) with A a local CSR block whose column
    /// ids are GLOBAL over the k dimension (Eq. 27).
    pub fn spmm(
        &self,
        a_local: &Csr,
        a_row_bounds: &Arc<Vec<usize>>,
        row_axis: Axis,
        k_axis: Axis,
        b: &PmmMat,
    ) -> PmmMat {
        assert_eq!(k_axis, b.layout.row_axis);
        let (k0, _k1) = self.my_block(&b.row_bounds, k_axis);
        let d = b.local.cols;
        let mut out = Mat::zeros(a_local.rows, d);
        self.time(
            || {
                for r in 0..a_local.rows {
                    let (cs, vs) = a_local.row(r);
                    let orow = &mut out.data[r * d..(r + 1) * d];
                    for (&c, &v) in cs.iter().zip(vs) {
                        let br = c as usize - k0;
                        let brow = &b.local.data[br * d..(br + 1) * d];
                        crate::tensor::simd::axpy(orow, v, brow);
                    }
                }
            },
            |t| &mut t.spmm,
        );
        self.all_reduce(k_axis, &mut out.data, self.tp_precision);
        PmmMat {
            layout: Layout::new(row_axis, b.layout.col_axis),
            row_bounds: a_row_bounds.clone(),
            col_bounds: b.col_bounds.clone(),
            local: out,
        }
    }

    /// Transposed sparse mm: A_csr(k,r)^T @ B(k,c) (Eq. 17): scatter rows of
    /// B through the transposed edges.  The output row space is A's column
    /// (global) dimension restricted to this rank's `r_axis` block.
    #[allow(clippy::too_many_arguments)]
    pub fn spmm_ta(
        &self,
        a_local: &Csr,
        out_row_bounds: &Arc<Vec<usize>>,
        out_row_axis: Axis,
        k_axis: Axis,
        b: &PmmMat,
    ) -> PmmMat {
        assert_eq!(k_axis, b.layout.row_axis);
        let (o0, o1) = self.my_block(&out_row_bounds, out_row_axis);
        let d = b.local.cols;
        let mut out = Mat::zeros(o1 - o0, d);
        debug_assert_eq!(a_local.rows, b.local.rows);
        self.time(
            || {
                for r in 0..a_local.rows {
                    let (cs, vs) = a_local.row(r);
                    let brow = &b.local.data[r * d..(r + 1) * d];
                    for (&c, &v) in cs.iter().zip(vs) {
                        let or = c as usize - o0;
                        let orow = &mut out.data[or * d..(or + 1) * d];
                        crate::tensor::simd::axpy(orow, v, brow);
                    }
                }
            },
            |t| &mut t.spmm,
        );
        self.all_reduce(k_axis, &mut out.data, self.tp_precision);
        PmmMat {
            layout: Layout::new(out_row_axis, b.layout.col_axis),
            row_bounds: out_row_bounds.clone(),
            col_bounds: b.col_bounds.clone(),
            local: out,
        }
    }

    /// Parallel RMSNorm with learned scale (Eq. 29): the sum of squares is
    /// all-reduced across the column axis in FP32.  Returns (out, inv_rms).
    /// `g` is this rank's slice of the scale vector over the column axis.
    pub fn rmsnorm_slice(&self, x: &PmmMat, g: &[f32]) -> (PmmMat, Vec<f32>) {
        assert_eq!(g.len(), x.local.cols);
        let dh = x.global_cols();
        let rows = x.local.rows;
        let mut sumsq: Vec<f32> = self.time(
            || {
                (0..rows)
                    .map(|r| x.local.row(r).iter().map(|v| v * v).sum())
                    .collect()
            },
            |t| &mut t.elementwise,
        );
        // numerically sensitive: always FP32 (§V-B)
        self.all_reduce(x.layout.col_axis, &mut sumsq, Precision::Fp32);
        let inv: Vec<f32> = sumsq.iter().map(|&s| 1.0 / (s / dh as f32 + RMS_EPS).sqrt()).collect();
        let mut out = x.clone();
        self.time(
            || {
                for r in 0..rows {
                    let orow = &mut out.local.data[r * x.local.cols..(r + 1) * x.local.cols];
                    for j in 0..x.local.cols {
                        orow[j] *= inv[r] * g[j];
                    }
                }
            },
            |t| &mut t.elementwise,
        );
        (out, inv)
    }

    /// As `rmsnorm_slice` but with the scale carried as a sharded matrix.
    pub fn rmsnorm(&self, x: &PmmMat, g: &PmmMat) -> (PmmMat, Vec<f32>) {
        self.rmsnorm_slice(x, &g.local.data.clone())
    }

    /// Reshard `m` to `new_layout` (row/col bounds given) by two line
    /// all-gathers + slice (§IV-C4 residual resharding).
    pub fn reshard(
        &self,
        m: &PmmMat,
        new_layout: Layout,
        new_rb: Arc<Vec<usize>>,
        new_cb: Arc<Vec<usize>>,
    ) -> PmmMat {
        // gather along current row axis -> full rows of my column strip;
        // activation gathers ride at the spec's precision (§V-B): bf16
        // halves the dominant 3D-PMM gather volume
        let prec = self.tp_precision;
        let row_parts = self.time(
            || self.world.all_gather(self.rank, m.layout.row_axis, &m.local.data, prec),
            |t| &mut t.reshard,
        );
        let cols_local = m.local.cols;
        let mut strip = Mat::zeros(m.global_rows(), cols_local);
        for (i, part) in row_parts.iter().enumerate() {
            let (r0, r1) = (m.row_bounds[i], m.row_bounds[i + 1]);
            debug_assert_eq!(part.len(), (r1 - r0) * cols_local);
            strip.data[r0 * cols_local..r1 * cols_local].copy_from_slice(part);
        }
        // gather strips along current col axis -> full matrix
        let col_parts = self.time(
            || self.world.all_gather(self.rank, m.layout.col_axis, &strip.data, prec),
            |t| &mut t.reshard,
        );
        let mut full = Mat::zeros(m.global_rows(), m.global_cols());
        for (i, part) in col_parts.iter().enumerate() {
            let (c0, c1) = (m.col_bounds[i], m.col_bounds[i + 1]);
            let w = c1 - c0;
            for r in 0..full.rows {
                full.data[r * full.cols + c0..r * full.cols + c1]
                    .copy_from_slice(&part[r * w..(r + 1) * w]);
            }
        }
        // slice my new block
        let (r0, r1) = self.my_block(&new_rb, new_layout.row_axis);
        let (c0, c1) = self.my_block(&new_cb, new_layout.col_axis);
        PmmMat {
            layout: new_layout,
            row_bounds: new_rb,
            col_bounds: new_cb,
            local: full.slice(r0, r1, c0, c1),
        }
    }

    /// Gather a sharded matrix into the full global matrix (tests/eval).
    pub fn gather_global(&self, m: &PmmMat) -> Mat {
        let row_parts =
            self.world.all_gather(self.rank, m.layout.row_axis, &m.local.data, Precision::Fp32);
        let cols_local = m.local.cols;
        let mut strip = Mat::zeros(m.global_rows(), cols_local);
        for (i, part) in row_parts.iter().enumerate() {
            let (r0, r1) = (m.row_bounds[i], m.row_bounds[i + 1]);
            strip.data[r0 * cols_local..r1 * cols_local].copy_from_slice(part);
        }
        let col_parts =
            self.world.all_gather(self.rank, m.layout.col_axis, &strip.data, Precision::Fp32);
        let mut full = Mat::zeros(m.global_rows(), m.global_cols());
        for (i, part) in col_parts.iter().enumerate() {
            let (c0, c1) = (m.col_bounds[i], m.col_bounds[i + 1]);
            let w = c1 - c0;
            for r in 0..full.rows {
                full.data[r * full.cols + c0..r * full.cols + c1]
                    .copy_from_slice(&part[r * w..(r + 1) * w]);
            }
        }
        full
    }
}

/// Deterministic dropout mask for a shard: every replica (and every rank
/// holding the same block) derives identical values because the stream is
/// keyed on (seed, step, layer, block coordinates) only.
pub fn shard_dropout_mask(
    seed: u64,
    step: u64,
    layer: usize,
    rows: usize,
    cols: usize,
    row_off: usize,
    col_off: usize,
    global_cols: usize,
    dropout: f32,
) -> Mat {
    let keep = 1.0 - dropout;
    let mut m = Mat::zeros(rows, cols);
    for r in 0..rows {
        // one RNG per global row so any row partition sees the same stream
        let key = splitmix64(seed ^ step.wrapping_mul(0x9E37_79B9))
            ^ ((layer as u64) << 48)
            ^ ((row_off + r) as u64).wrapping_mul(0xD129_42FD);
        let mut rng = Rng::new(key);
        // advance to the column offset (cheap: one draw per column)
        for _ in 0..col_off {
            rng.f32();
        }
        let mrow = &mut m.data[r * cols..(r + 1) * cols];
        for v in mrow.iter_mut() {
            if rng.f32() < keep {
                *v = 1.0 / keep;
            }
        }
    }
    let _ = global_cols;
    m
}

// Re-export the submodule with the full GCN engine.
mod engine;
pub use engine::{PmmGcn, PmmStepOutput, PmmTimers};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommWorld;
    use crate::grid::Grid4D;

    /// Run the same closure on every rank thread of a 3D grid.
    fn run_grid<F, T>(grid: Grid4D, f: F) -> Vec<T>
    where
        F: Fn(PmmCtx) -> T + Send + Sync + 'static,
        T: Send + 'static,
    {
        let world = Arc::new(CommWorld::new(grid));
        let f = Arc::new(f);
        let mut hs = vec![];
        for r in 0..grid.world_size() {
            let w = world.clone();
            let f = f.clone();
            hs.push(std::thread::spawn(move || {
                f(PmmCtx::new(grid, r, &w, Precision::Fp32))
            }));
        }
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn global_mats(seed: u64, m: usize, k: usize, n: usize) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        (Mat::randn(m, k, &mut rng, 1.0), Mat::randn(k, n, &mut rng, 1.0))
    }

    #[test]
    fn feature_layouts_have_period_three() {
        let ls = feature_layouts(6);
        assert_eq!(ls[0], Layout::new(Axis::X, Axis::Y));
        assert_eq!(ls[1], Layout::new(Axis::Z, Axis::X));
        assert_eq!(ls[2], Layout::new(Axis::Y, Axis::Z));
        assert_eq!(ls[3], ls[0]);
        assert_eq!(ls[4], ls[1]);
    }

    #[test]
    fn mm_matches_serial_on_2x2x2() {
        let grid = Grid4D::new(1, 2, 2, 2);
        let (a, b) = global_mats(1, 12, 10, 8);
        let want = a.matmul(&b);
        let aa = a.clone();
        let bb = b.clone();
        let outs = run_grid(grid, move |ctx| {
            let pa = ctx.shard_from_global(&aa, Layout::new(Axis::X, Axis::Y));
            let pb = ctx.shard_from_global(&bb, Layout::new(Axis::Y, Axis::Z));
            let c = ctx.mm(&pa, &pb);
            assert_eq!(c.layout, Layout::new(Axis::X, Axis::Z));
            ctx.gather_global(&c)
        });
        for o in outs {
            assert!(o.allclose(&want, 1e-3, 1e-3), "diff {}", o.max_abs_diff(&want));
        }
    }

    #[test]
    fn mm_ta_and_tb_match_serial() {
        let grid = Grid4D::new(1, 2, 1, 2);
        let mut rng = Rng::new(2);
        let a = Mat::randn(10, 6, &mut rng, 1.0);
        let b = Mat::randn(10, 8, &mut rng, 1.0);
        let want_ta = a.t_matmul(&b);
        let aa = a.clone();
        let bb = b.clone();
        let outs = run_grid(grid, move |ctx| {
            let pa = ctx.shard_from_global(&aa, Layout::new(Axis::X, Axis::Z));
            let pb = ctx.shard_from_global(&bb, Layout::new(Axis::X, Axis::Y));
            let c = ctx.mm_ta(&pa, &pb);
            assert_eq!(c.layout, Layout::new(Axis::Z, Axis::Y));
            ctx.gather_global(&c)
        });
        for o in outs {
            assert!(o.allclose(&want_ta, 1e-3, 1e-3));
        }

        let (a2, b2t) = global_mats(3, 9, 7, 5); // a2: 9x7 ; b2t: 7x5 -> b2: 5x7
        let b2 = b2t.transpose();
        let want_tb = a2.matmul_t(&b2);
        let outs = run_grid(Grid4D::new(1, 2, 2, 1), move |ctx| {
            let pa = ctx.shard_from_global(&a2, Layout::new(Axis::X, Axis::Y));
            let pb = ctx.shard_from_global(&b2, Layout::new(Axis::Z, Axis::Y));
            let c = ctx.mm_tb(&pa, &pb);
            assert_eq!(c.layout, Layout::new(Axis::X, Axis::Z));
            ctx.gather_global(&c)
        });
        for o in outs {
            assert!(o.allclose(&want_tb, 1e-3, 1e-3));
        }
    }

    #[test]
    fn rmsnorm_matches_serial() {
        let grid = Grid4D::new(1, 2, 2, 1);
        let mut rng = Rng::new(4);
        let x = Mat::randn(8, 12, &mut rng, 1.5);
        let g = Mat::randn(1, 12, &mut rng, 0.5);
        let (want, _) = crate::tensor::rmsnorm(&x, g.row(0), RMS_EPS);
        let xx = x.clone();
        let gg = g.clone();
        let outs = run_grid(grid, move |ctx| {
            let px = ctx.shard_from_global(&xx, Layout::new(Axis::X, Axis::Y));
            let pg = ctx.shard_from_global(&gg, Layout::new(Axis::Z, Axis::Y));
            let (out, _) = ctx.rmsnorm(&px, &pg);
            ctx.gather_global(&out)
        });
        for o in outs {
            assert!(o.allclose(&want, 1e-4, 1e-4));
        }
    }

    #[test]
    fn reshard_preserves_content() {
        let grid = Grid4D::new(1, 2, 2, 2);
        let mut rng = Rng::new(5);
        let x = Mat::randn(10, 6, &mut rng, 1.0);
        let xx = x.clone();
        let outs = run_grid(grid, move |ctx| {
            let px = ctx.shard_from_global(&xx, Layout::new(Axis::X, Axis::Y));
            let new_layout = Layout::new(Axis::Z, Axis::X);
            let rb = ctx.static_bounds(10, Axis::Z);
            let cb = ctx.static_bounds(6, Axis::X);
            let moved = ctx.reshard(&px, new_layout, rb, cb);
            ctx.gather_global(&moved)
        });
        for o in outs {
            assert!(o.allclose(&x, 1e-6, 0.0));
        }
    }

    #[test]
    fn shard_dropout_mask_is_partition_invariant() {
        // mask generated over a whole block equals the concatenation of the
        // masks of its sub-blocks (row and column splits)
        let full = shard_dropout_mask(9, 3, 1, 8, 10, 0, 0, 10, 0.5);
        let top = shard_dropout_mask(9, 3, 1, 4, 10, 0, 0, 10, 0.5);
        let bottom = shard_dropout_mask(9, 3, 1, 4, 10, 4, 0, 10, 0.5);
        assert_eq!(&full.data[..40], &top.data[..]);
        assert_eq!(&full.data[40..], &bottom.data[..]);
        let left = shard_dropout_mask(9, 3, 1, 8, 4, 0, 0, 10, 0.5);
        let right = shard_dropout_mask(9, 3, 1, 8, 6, 0, 4, 10, 0.5);
        for r in 0..8 {
            assert_eq!(&full.data[r * 10..r * 10 + 4], left.row(r));
            assert_eq!(&full.data[r * 10 + 4..r * 10 + 10], right.row(r));
        }
    }
}
