//! The 4D virtual process grid `Gd x Gx x Gy x Gz` (paper §IV).
//!
//! Data parallelism across `Gd` groups; within a group, 3D PMM across
//! `Gx x Gy x Gz`.  Ranks are numbered so that a DP group is a contiguous
//! block (`d` is the slowest-varying coordinate), matching how launchers
//! place replicas on adjacent nodes.

/// 4D grid shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid4D {
    /// Number of data-parallel groups.
    pub gd: usize,
    /// 3D PMM extent along X (fastest-varying rank coordinate).
    pub gx: usize,
    /// 3D PMM extent along Y.
    pub gy: usize,
    /// 3D PMM extent along Z.
    pub gz: usize,
}

/// Coordinates of one rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Coord {
    /// Data-parallel group index (slowest-varying).
    pub d: usize,
    /// X coordinate within the group (fastest-varying).
    pub x: usize,
    /// Y coordinate within the group.
    pub y: usize,
    /// Z coordinate within the group.
    pub z: usize,
}

/// The communication axes used by the 3D PMM algorithm and DP sync.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Axis {
    /// Tensor-parallel axis X (ranks varying in `x`, fixed d/y/z).
    X,
    /// Tensor-parallel axis Y.
    Y,
    /// Tensor-parallel axis Z.
    Z,
    /// data-parallel gradient all-reduce group (across `d`, fixed x/y/z)
    Dp,
}

impl Axis {
    /// All four axes in canonical (indexing / wire) order.
    pub const ALL: [Axis; 4] = [Axis::X, Axis::Y, Axis::Z, Axis::Dp];

    /// Dense index of this axis (X=0, Y=1, Z=2, Dp=3) — the order used by
    /// per-axis arrays throughout `comm` and `pmm`.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Single-byte wire code of this axis (same value as [`Axis::index`];
    /// decode with [`Axis::from_code`]).
    pub fn code(self) -> u8 {
        self as u8
    }

    /// Inverse of [`Axis::code`]; `None` for an unknown byte (a malformed
    /// frame, surfaced as a decode error rather than a panic).
    pub fn from_code(c: u8) -> Option<Axis> {
        match c {
            0 => Some(Axis::X),
            1 => Some(Axis::Y),
            2 => Some(Axis::Z),
            3 => Some(Axis::Dp),
            _ => None,
        }
    }

    /// Lowercase report tag ("x", "y", "z", "dp") used by `RunReport`
    /// axis stats and failure records.
    pub fn tag(self) -> &'static str {
        match self {
            Axis::X => "x",
            Axis::Y => "y",
            Axis::Z => "z",
            Axis::Dp => "dp",
        }
    }
}

impl Grid4D {
    /// Grid of `gd` DP groups, each a `gx x gy x gz` PMM block (all > 0).
    pub fn new(gd: usize, gx: usize, gy: usize, gz: usize) -> Grid4D {
        assert!(gd > 0 && gx > 0 && gy > 0 && gz > 0);
        Grid4D { gd, gx, gy, gz }
    }

    /// Parse "dxXxYxZ" (e.g. "2x2x2x1") or "XxYxZ" (gd=1).
    pub fn parse(s: &str) -> Option<Grid4D> {
        let parts: Vec<usize> = s.split('x').map(|p| p.parse().ok()).collect::<Option<_>>()?;
        match parts[..] {
            [gx, gy, gz] => Some(Grid4D::new(1, gx, gy, gz)),
            [gd, gx, gy, gz] => Some(Grid4D::new(gd, gx, gy, gz)),
            _ => None,
        }
    }

    /// Total number of ranks (`gd * gx * gy * gz`).
    pub fn world_size(&self) -> usize {
        self.gd * self.gx * self.gy * self.gz
    }

    /// Ranks per data-parallel group (`gx * gy * gz`).
    pub fn group_size(&self) -> usize {
        self.gx * self.gy * self.gz
    }

    /// rank -> (d, x, y, z); x fastest-varying within a group.
    pub fn coord(&self, rank: usize) -> Coord {
        assert!(rank < self.world_size());
        let group = self.group_size();
        let d = rank / group;
        let r = rank % group;
        let z = r / (self.gx * self.gy);
        let rem = r % (self.gx * self.gy);
        let y = rem / self.gx;
        let x = rem % self.gx;
        Coord { d, x, y, z }
    }

    /// Inverse of `coord`: (d, x, y, z) -> rank.
    pub fn rank(&self, c: Coord) -> usize {
        debug_assert!(c.d < self.gd && c.x < self.gx && c.y < self.gy && c.z < self.gz);
        ((c.d * self.gz + c.z) * self.gy + c.y) * self.gx + c.x
    }

    /// Size of the process group along `axis`.
    pub fn axis_size(&self, axis: Axis) -> usize {
        match axis {
            Axis::X => self.gx,
            Axis::Y => self.gy,
            Axis::Z => self.gz,
            Axis::Dp => self.gd,
        }
    }

    /// The ranks of `rank`'s process group along `axis` (including itself),
    /// ordered by the axis coordinate.
    pub fn group_ranks(&self, rank: usize, axis: Axis) -> Vec<usize> {
        let c = self.coord(rank);
        (0..self.axis_size(axis))
            .map(|i| {
                let mut cc = c;
                match axis {
                    Axis::X => cc.x = i,
                    Axis::Y => cc.y = i,
                    Axis::Z => cc.z = i,
                    Axis::Dp => cc.d = i,
                }
                self.rank(cc)
            })
            .collect()
    }

    /// Stable id of `rank`'s group along `axis` (ranks in the same group
    /// share the id; ids are dense per axis starting at 0).
    pub fn group_id(&self, rank: usize, axis: Axis) -> usize {
        let c = self.coord(rank);
        match axis {
            Axis::X => (c.d * self.gz + c.z) * self.gy + c.y,
            Axis::Y => (c.d * self.gz + c.z) * self.gx + c.x,
            Axis::Z => (c.d * self.gy + c.y) * self.gx + c.x,
            Axis::Dp => (c.z * self.gy + c.y) * self.gx + c.x,
        }
    }

    /// Number of distinct groups along `axis`.
    pub fn num_groups(&self, axis: Axis) -> usize {
        self.world_size() / self.axis_size(axis)
    }

    /// Index of `rank` within its `axis` group.
    pub fn index_in_group(&self, rank: usize, axis: Axis) -> usize {
        let c = self.coord(rank);
        match axis {
            Axis::X => c.x,
            Axis::Y => c.y,
            Axis::Z => c.z,
            Axis::Dp => c.d,
        }
    }
}

/// Pick a near-cubic (gx, gy, gz) for `g` ranks per group, as the paper does
/// for its scaling experiments ("as close to a cube as possible", §VII-C).
pub fn near_cubic(g: usize) -> (usize, usize, usize) {
    let mut best = (g, 1, 1);
    let mut best_score = usize::MAX;
    for x in 1..=g {
        if g % x != 0 {
            continue;
        }
        let rem = g / x;
        for y in 1..=rem {
            if rem % y != 0 {
                continue;
            }
            let z = rem / y;
            let (mx, mn) = (x.max(y).max(z), x.min(y).min(z));
            let score = mx - mn;
            if score < best_score {
                best_score = score;
                best = (x, y, z);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_coord_bijective() {
        let g = Grid4D::new(3, 2, 4, 2);
        for r in 0..g.world_size() {
            assert_eq!(g.rank(g.coord(r)), r);
        }
    }

    #[test]
    fn dp_groups_are_contiguous() {
        let g = Grid4D::new(2, 2, 2, 2);
        for r in 0..8 {
            assert_eq!(g.coord(r).d, 0);
        }
        for r in 8..16 {
            assert_eq!(g.coord(r).d, 1);
        }
    }

    #[test]
    fn group_ranks_share_group_id_and_partition_world() {
        let g = Grid4D::new(2, 2, 3, 2);
        for axis in [Axis::X, Axis::Y, Axis::Z, Axis::Dp] {
            let mut seen = vec![0usize; g.world_size()];
            for r in 0..g.world_size() {
                let members = g.group_ranks(r, axis);
                assert_eq!(members.len(), g.axis_size(axis));
                assert!(members.contains(&r));
                let id = g.group_id(r, axis);
                assert!(id < g.num_groups(axis));
                for &m in &members {
                    assert_eq!(g.group_id(m, axis), id, "axis {axis:?}");
                }
                seen[r] += 1;
            }
            assert!(seen.iter().all(|&s| s == 1));
        }
    }

    #[test]
    fn index_in_group_is_position_in_member_list() {
        let g = Grid4D::new(2, 3, 2, 2);
        for r in 0..g.world_size() {
            for axis in [Axis::X, Axis::Y, Axis::Z, Axis::Dp] {
                let members = g.group_ranks(r, axis);
                assert_eq!(members[g.index_in_group(r, axis)], r);
            }
        }
    }

    #[test]
    fn parse_formats() {
        assert_eq!(Grid4D::parse("2x2x2"), Some(Grid4D::new(1, 2, 2, 2)));
        assert_eq!(Grid4D::parse("4x2x2x1"), Some(Grid4D::new(4, 2, 2, 1)));
        assert_eq!(Grid4D::parse("2x2"), None);
        assert_eq!(Grid4D::parse("axb"), None);
    }

    #[test]
    fn axis_codes_round_trip() {
        for a in Axis::ALL {
            assert_eq!(Axis::from_code(a.code()), Some(a));
            assert_eq!(a.index(), a.code() as usize);
        }
        assert_eq!(Axis::from_code(4), None);
        assert_eq!(Axis::Dp.tag(), "dp");
    }

    #[test]
    fn near_cubic_prefers_cubes() {
        assert_eq!(near_cubic(8), (2, 2, 2));
        assert_eq!(near_cubic(27), (3, 3, 3));
        let (x, y, z) = near_cubic(16);
        assert_eq!(x * y * z, 16);
        assert!(x.max(y).max(z) <= 4);
        assert_eq!(near_cubic(1), (1, 1, 1));
    }
}
