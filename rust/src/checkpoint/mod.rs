//! Versioned binary training-state snapshots with torn-write detection.
//!
//! The paper's headline runs live at 1024–2048 devices, where preemption
//! and node loss are routine.  The communication-free sampling contract
//! (§IV-B) makes recovery unusually cheap here: every rank reconstructs
//! its mini-batch stream from just `(seed, step)`, so a snapshot of the
//! model parameters, the Adam moments, the RNG state and the step cursor
//! is *sufficient* for a **bitwise-identical** resume — no sampler state,
//! no in-flight batches, no peer coordination.
//!
//! # Snapshot format (version 1, little-endian)
//!
//! ```text
//! fixed header (80 B): magic "PALLASC1" | version u32 | flags u32
//!                      | step u64 (completed steps = next step index)
//!                      | seed u64 | spec_hash u64
//!                      | rng state 4 x u64 (xoshiro256++ words)
//!                      | adam t (f32 bits) u32 | n_tensors u32
//! tensor table:        n_tensors x u64        element count per tensor
//! payload:             params, then Adam m, then Adam v — each group is
//!                      n_tensors tensors of f32, in parameter-slot order
//! trailer (4 B):       CRC32 (IEEE) over every preceding byte
//! ```
//!
//! The layout is a pure function of the tensor table, so the expected file
//! size is known up front; [`load`] validates magic, version, exact length
//! AND the payload checksum and returns a clean error — never a panic — on
//! truncated, stale-version or bit-flipped files.  [`save`] writes through
//! a pid-unique `.tmp` sibling, fsyncs, then renames into place (the same
//! atomic discipline as the `.pallas` container, `graph::store::pack`), so
//! a crash mid-save never leaves a torn file at a snapshot path; a torn
//! `.tmp` is simply never picked up because [`latest_valid`] only
//! considers `*.ckpt` names.  Retention is keep-last-K ([`prune`]).

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::{AdamState, Params};
use crate::util::bytes::{f32_le, u32_le, u64_le};
use crate::util::rng::{splitmix64, Rng};

/// File magic: "PALLASC1" (pallas checkpoint, generation 1).
pub const MAGIC: [u8; 8] = *b"PALLASC1";
/// Current snapshot format version.
pub const VERSION: u32 = 1;
/// Fixed header size in bytes (everything before the tensor table).
pub const FIXED_HEADER_BYTES: usize = 80;
/// Trailing checksum size in bytes.
pub const TRAILER_BYTES: usize = 4;

// CRC32 (IEEE 802.3, reflected 0xEDB88320) lookup table, built at compile
// time — the offline toolchain has no checksum crate.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 (IEEE) of `bytes` — the payload checksum of the snapshot trailer.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// Streaming CRC32 (IEEE) state: feed chunks with [`Crc32::update`], read
/// the digest with [`Crc32::finish`].  Chunking does not change the digest
/// (`crc32(a ++ b)` equals streaming `a` then `b`), which is what lets the
/// `.pallas` section checksums be computed and verified with a bounded
/// buffer instead of materializing whole sections.
#[derive(Clone, Copy, Debug)]
pub struct Crc32(u32);

impl Crc32 {
    /// Fresh CRC state (the IEEE init value).
    #[allow(clippy::new_without_default)]
    pub fn new() -> Crc32 {
        Crc32(0xFFFF_FFFF)
    }

    /// Fold `bytes` into the running digest.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.0;
        for &b in bytes {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.0 = c;
    }

    /// Final digest of everything fed so far (the state stays usable).
    pub fn finish(&self) -> u32 {
        self.0 ^ 0xFFFF_FFFF
    }
}

/// Deterministic order-sensitive hash of a run configuration, stored in
/// the snapshot header so resume refuses state from a *different* run
/// (other dims, other seed, other backend) with a descriptive error
/// instead of silently training on mismatched tensors.
pub fn state_hash(parts: &[u64]) -> u64 {
    parts
        .iter()
        .fold(0xC0FF_EE00_D15E_A5E5u64, |h, &p| splitmix64(h ^ p))
}

/// One decoded training-state snapshot: everything a backend needs for a
/// bitwise-identical resume at `step`.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    /// Completed steps — the index of the next step to execute on resume.
    pub step: u64,
    /// The run's sampling / parameter-init seed (with `step`, this is the
    /// whole §IV-B communication-free sampler cursor).
    pub seed: u64,
    /// [`state_hash`] of the run configuration that wrote the snapshot.
    pub spec_hash: u64,
    /// Full xoshiro256++ state of the step-`step` RNG stream
    /// (`Rng::for_step(seed, step)` — recorded for auditability; engines
    /// re-derive every per-step stream from `(seed, step)`).
    pub rng: [u64; 4],
    /// Adam step counter `t` (f32, mirroring the artifact scalar).
    pub t: f32,
    /// Parameter tensors in slot order, flattened row-major.
    pub tensors: Vec<Vec<f32>>,
    /// Adam first moments, same order/shapes as `tensors`.
    pub m: Vec<Vec<f32>>,
    /// Adam second moments, same order/shapes as `tensors`.
    pub v: Vec<Vec<f32>>,
}

impl Snapshot {
    /// Assemble a snapshot from flat tensor groups (the PMM engine's
    /// export format).  The RNG words are derived from `(seed, step)`.
    pub fn from_flat(
        step: u64,
        seed: u64,
        spec_hash: u64,
        tensors: Vec<Vec<f32>>,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
        t: f32,
    ) -> Snapshot {
        Snapshot {
            step,
            seed,
            spec_hash,
            rng: Rng::for_step(seed, step).state(),
            t,
            tensors,
            m,
            v,
        }
    }

    /// Snapshot the reference-model state (`model::Params` +
    /// [`AdamState`]) after `step` completed steps.
    pub fn from_model(
        step: u64,
        seed: u64,
        spec_hash: u64,
        params: &Params,
        opt: &AdamState,
    ) -> Snapshot {
        Snapshot::from_flat(
            step,
            seed,
            spec_hash,
            params.iter().map(|p| p.data.clone()).collect(),
            opt.m.iter().map(|p| p.data.clone()).collect(),
            opt.v.iter().map(|p| p.data.clone()).collect(),
            opt.t,
        )
    }

    /// Restore the reference-model state in place; every tensor length is
    /// validated against the live shapes before anything is written.
    pub fn restore_model(&self, params: &mut Params, opt: &mut AdamState) -> Result<()> {
        if self.tensors.len() != params.len() {
            bail!(
                "checkpoint holds {} tensors but the model has {}",
                self.tensors.len(),
                params.len()
            );
        }
        if self.m.len() != params.len() || self.v.len() != params.len() {
            bail!("checkpoint moment groups do not match its parameter count");
        }
        for (i, (t, p)) in self.tensors.iter().zip(params.iter()).enumerate() {
            if t.len() != p.data.len() || self.m[i].len() != t.len() || self.v[i].len() != t.len()
            {
                bail!(
                    "checkpoint tensor {i} has {} elements but the model expects {}",
                    t.len(),
                    p.data.len()
                );
            }
        }
        for (((p, t), (m, sm)), (v, sv)) in params
            .iter_mut()
            .zip(&self.tensors)
            .zip(opt.m.iter_mut().zip(&self.m))
            .zip(opt.v.iter_mut().zip(&self.v))
        {
            p.data.copy_from_slice(t);
            m.data.copy_from_slice(sm);
            v.data.copy_from_slice(sv);
        }
        opt.t = self.t;
        Ok(())
    }

    /// Refuse a snapshot written by a different run configuration.
    pub fn check_hash(&self, expected: u64, what: &str) -> Result<()> {
        if self.spec_hash != expected {
            bail!(
                "checkpoint for {what}: run-configuration hash mismatch (snapshot \
                 {:#018x}, current run {:#018x}) — refusing to resume a different \
                 model/seed/backend configuration",
                self.spec_hash,
                expected
            );
        }
        Ok(())
    }

    /// Serialize to the on-disk byte layout, checksum included.
    pub fn encode(&self) -> Vec<u8> {
        let elems: usize = self.tensors.iter().map(Vec::len).sum();
        let mut out =
            Vec::with_capacity(FIXED_HEADER_BYTES + 8 * self.tensors.len() + 12 * elems + 4);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes()); // flags (reserved)
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.spec_hash.to_le_bytes());
        for w in self.rng {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.extend_from_slice(&self.t.to_bits().to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            out.extend_from_slice(&(t.len() as u64).to_le_bytes());
        }
        for group in [&self.tensors, &self.m, &self.v] {
            for t in group {
                for &x in t {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Decode and validate a snapshot from raw bytes; `origin` names the
    /// file in every error.  Never panics: truncation, bad magic, stale
    /// versions, impossible tensor tables and checksum mismatches all
    /// surface as descriptive errors.
    pub fn decode(bytes: &[u8], origin: &Path) -> Result<Snapshot> {
        let show = origin.display();
        let min = FIXED_HEADER_BYTES + TRAILER_BYTES;
        if bytes.len() < min {
            bail!("checkpoint {show}: truncated ({} bytes, need at least {min})", bytes.len());
        }
        if bytes[..8] != MAGIC {
            bail!("checkpoint {show}: bad magic (not a pallas checkpoint)");
        }
        let version = u32_le(&bytes[8..12]);
        if version != VERSION {
            bail!("checkpoint {show}: unsupported version {version} (this build reads {VERSION})");
        }
        let step = u64_le(&bytes[16..24]);
        let seed = u64_le(&bytes[24..32]);
        let spec_hash = u64_le(&bytes[32..40]);
        let mut rng = [0u64; 4];
        for (i, w) in rng.iter_mut().enumerate() {
            *w = u64_le(&bytes[40 + 8 * i..48 + 8 * i]);
        }
        let t = f32_le(&bytes[72..76]);
        let n = u32_le(&bytes[76..80]) as usize;

        // expected size from the tensor table, all checked arithmetic so a
        // corrupt header is rejected instead of overflowing
        let table_end = (FIXED_HEADER_BYTES as u64)
            .checked_add((n as u64).checked_mul(8).unwrap_or(u64::MAX))
            .unwrap_or(u64::MAX);
        if table_end > bytes.len() as u64 {
            bail!("checkpoint {show}: truncated inside the tensor table ({n} tensors)");
        }
        let mut lens = Vec::with_capacity(n);
        let mut total_elems: u64 = 0;
        for i in 0..n {
            let off = FIXED_HEADER_BYTES + 8 * i;
            let len = u64_le(&bytes[off..off + 8]);
            total_elems = total_elems
                .checked_add(len)
                .ok_or_else(|| anyhow::anyhow!("checkpoint {show}: tensor table overflows"))?;
            lens.push(len);
        }
        let expected = total_elems
            .checked_mul(12)
            .and_then(|p| p.checked_add(table_end))
            .and_then(|p| p.checked_add(TRAILER_BYTES as u64))
            .ok_or_else(|| anyhow::anyhow!("checkpoint {show}: tensor table overflows"))?;
        if (bytes.len() as u64) < expected {
            bail!(
                "checkpoint {show}: truncated ({} bytes, the header implies {expected})",
                bytes.len()
            );
        }
        if (bytes.len() as u64) > expected {
            bail!(
                "checkpoint {show}: length mismatch ({} bytes, the header implies {expected})",
                bytes.len()
            );
        }
        let body = &bytes[..bytes.len() - TRAILER_BYTES];
        let stored = u32_le(&bytes[bytes.len() - 4..]);
        let computed = crc32(body);
        if stored != computed {
            bail!(
                "checkpoint {show}: checksum mismatch (stored {stored:08x}, computed \
                 {computed:08x}) — the payload is corrupt"
            );
        }

        let mut off = table_end as usize;
        let mut read_group = |lens: &[u64]| -> Vec<Vec<f32>> {
            lens.iter()
                .map(|&len| {
                    let end = off + 4 * len as usize;
                    let t: Vec<f32> = bytes[off..end].chunks_exact(4).map(f32_le).collect();
                    off = end;
                    t
                })
                .collect()
        };
        let tensors = read_group(&lens);
        let m = read_group(&lens);
        let v = read_group(&lens);
        Ok(Snapshot { step, seed, spec_hash, rng, t, tensors, m, v })
    }
}

/// Canonical snapshot path: `dir/{tag}-step{step:012}.ckpt` (zero-padded
/// so lexical order equals step order).
pub fn path_for(dir: &Path, tag: &str, step: u64) -> PathBuf {
    dir.join(format!("{tag}-step{step:012}.ckpt"))
}

/// `(step, path)` of every snapshot file of `tag` in `dir`, ascending by
/// step.  A missing directory is an empty listing, not an error.
pub fn snapshot_files(dir: &Path, tag: &str) -> Vec<(u64, PathBuf)> {
    let prefix = format!("{tag}-step");
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(middle) = name.strip_prefix(&prefix).and_then(|s| s.strip_suffix(".ckpt"))
        else {
            continue;
        };
        if let Ok(step) = middle.parse::<u64>() {
            out.push((step, entry.path()));
        }
    }
    out.sort_unstable_by_key(|&(s, _)| s);
    out
}

/// Atomically write `snap` into `dir` under `tag` (creating `dir` if
/// needed) and return the snapshot path.  The bytes go to a pid-unique
/// `.tmp` sibling, are fsynced, and rename into place — a crash mid-save
/// never leaves a torn `.ckpt` file.
pub fn save(dir: &Path, tag: &str, snap: &Snapshot) -> Result<PathBuf> {
    if snap.m.len() != snap.tensors.len() || snap.v.len() != snap.tensors.len() {
        bail!(
            "snapshot moment group sizes ({}, {}) do not match its {} tensors",
            snap.m.len(),
            snap.v.len(),
            snap.tensors.len()
        );
    }
    for (i, t) in snap.tensors.iter().enumerate() {
        if snap.m[i].len() != t.len() || snap.v[i].len() != t.len() {
            bail!("snapshot tensor {i}: moment lengths do not match the parameter length");
        }
    }
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let path = path_for(dir, tag, snap.step);
    let tmp = {
        let mut os = path.as_os_str().to_os_string();
        os.push(format!(".tmp.{}", std::process::id()));
        PathBuf::from(os)
    };
    {
        let f = File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = std::io::BufWriter::new(f);
        w.write_all(&snap.encode())?;
        w.flush()?;
        // durable BEFORE the rename is journaled, or a crash could leave a
        // correct-length file with zeroed sections in place
        w.get_ref().sync_all()?;
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    if let Some(parent) = path.parent() {
        if let Ok(d) = File::open(parent) {
            let _ = d.sync_all();
        }
    }
    Ok(path)
}

/// Read and validate the snapshot at `path`.
pub fn load(path: &Path) -> Result<Snapshot> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    Snapshot::decode(&bytes, path)
}

/// Steps of every snapshot of `tag` that decodes cleanly, ascending, plus
/// one warning per torn/corrupt file that was skipped.
pub fn valid_steps(dir: &Path, tag: &str) -> (Vec<u64>, Vec<String>) {
    let mut steps = Vec::new();
    let mut warnings = Vec::new();
    for (step, path) in snapshot_files(dir, tag) {
        match load(&path) {
            Ok(_) => steps.push(step),
            Err(e) => warnings.push(format!("skipping {}: {e:#}", path.display())),
        }
    }
    (steps, warnings)
}

/// The newest snapshot of `tag` that decodes cleanly, skipping (and
/// reporting) torn or corrupt newer files — the recovery entry point: a
/// half-written or bit-flipped newest checkpoint falls back to the
/// previous valid one with a descriptive warning, never a panic.
pub fn latest_valid(dir: &Path, tag: &str) -> (Option<(PathBuf, Snapshot)>, Vec<String>) {
    let mut warnings = Vec::new();
    let mut files = snapshot_files(dir, tag);
    files.reverse(); // newest first
    for (_, path) in files {
        match load(&path) {
            Ok(s) => return (Some((path, s)), warnings),
            Err(e) => warnings.push(format!("skipping {}: {e:#}", path.display())),
        }
    }
    (None, warnings)
}

/// Keep-last-K retention: delete all but the newest `keep` snapshots of
/// `tag` (by step).  Returns one warning per file that could not be
/// removed; `keep == 0` is treated as 1 (never delete everything).
pub fn prune(dir: &Path, tag: &str, keep: usize) -> Vec<String> {
    let keep = keep.max(1);
    let files = snapshot_files(dir, tag);
    let mut warnings = Vec::new();
    if files.len() <= keep {
        return warnings;
    }
    for (_, path) in &files[..files.len() - keep] {
        if let Err(e) = std::fs::remove_file(path) {
            warnings.push(format!("could not prune {}: {e}", path.display()));
        }
    }
    warnings
}

/// How [`corrupt_newest`] damages a snapshot (deterministic fault
/// injection for crash-recovery tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorruptKind {
    /// Cut the file to half its length (a torn write).
    Truncate,
    /// Flip one payload bit (detected by the CRC32 trailer).
    FlipPayloadBit,
    /// Rewrite the version field to 0 (a stale/foreign format).
    StaleVersion,
}

/// Damage the newest snapshot of `tag` in place per `kind` and return its
/// path.  Test-support fault injector — intentionally *not* atomic.
pub fn corrupt_newest(dir: &Path, tag: &str, kind: CorruptKind) -> Result<PathBuf> {
    let (step, path) = snapshot_files(dir, tag)
        .pop()
        .ok_or_else(|| anyhow::anyhow!("no snapshot of tag '{tag}' in {}", dir.display()))?;
    let mut bytes = std::fs::read(&path)?;
    match kind {
        CorruptKind::Truncate => bytes.truncate(bytes.len() / 2),
        CorruptKind::FlipPayloadBit => {
            let mid = FIXED_HEADER_BYTES + (bytes.len() - FIXED_HEADER_BYTES) / 2;
            bytes[mid] ^= 0x10;
        }
        CorruptKind::StaleVersion => bytes[8..12].copy_from_slice(&0u32.to_le_bytes()),
    }
    std::fs::write(&path, &bytes)
        .with_context(|| format!("corrupting snapshot step {step} at {}", path.display()))?;
    Ok(path)
}

/// Where, how often and how many: the checkpoint knobs a run carries
/// (`RunSpec::checkpoint`, the trainer configs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckpointPolicy {
    /// Snapshot directory (shared by every rank of a run; tags disambiguate).
    pub dir: PathBuf,
    /// Save after every N-th step (`(step + 1) % N == 0`).
    pub every_steps: u64,
    /// Keep-last-K retention.
    pub keep: usize,
}

impl CheckpointPolicy {
    /// Policy with the given directory, cadence and retention.
    pub fn new(dir: impl Into<PathBuf>, every_steps: u64, keep: usize) -> CheckpointPolicy {
        CheckpointPolicy { dir: dir.into(), every_steps, keep }
    }

    /// Whether a snapshot is due after completing 0-based `step`.
    pub fn should_save(&self, step: u64) -> bool {
        self.every_steps > 0 && (step + 1) % self.every_steps == 0
    }
}

/// A policy bound to one shard tag: the save/restore handle a training
/// loop threads through its steps.
#[derive(Clone, Debug)]
pub struct CheckpointManager {
    policy: CheckpointPolicy,
    tag: String,
}

impl CheckpointManager {
    /// Bind `policy` to shard `tag` (`ooc`, `ref-g0`, `pmm-r3`, ...).
    pub fn new(policy: CheckpointPolicy, tag: &str) -> CheckpointManager {
        CheckpointManager { policy, tag: tag.to_string() }
    }

    /// Whether a snapshot is due after completing 0-based `step`.
    pub fn should_save(&self, step: u64) -> bool {
        self.policy.should_save(step)
    }

    /// Save `snap` atomically, then apply keep-last-K retention.
    pub fn save(&self, snap: &Snapshot) -> Result<PathBuf> {
        let path = save(&self.policy.dir, &self.tag, snap)?;
        for w in prune(&self.policy.dir, &self.tag, self.policy.keep) {
            eprintln!("warning: {w}");
        }
        Ok(path)
    }

    /// Newest valid snapshot of this tag (see [`latest_valid`]).
    pub fn latest(&self) -> (Option<(PathBuf, Snapshot)>, Vec<String>) {
        latest_valid(&self.policy.dir, &self.tag)
    }

    /// Valid snapshot steps of this tag, ascending (see [`valid_steps`]).
    pub fn valid_steps(&self) -> (Vec<u64>, Vec<String>) {
        valid_steps(&self.policy.dir, &self.tag)
    }

    /// The bound shard tag.
    pub fn tag(&self) -> &str {
        &self.tag
    }

    /// The bound policy.
    pub fn policy(&self) -> &CheckpointPolicy {
        &self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // the canonical IEEE CRC32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    fn sample_snapshot(step: u64) -> Snapshot {
        Snapshot::from_flat(
            step,
            42,
            state_hash(&[1, 2, 3]),
            vec![vec![1.0, -2.5, 3.25], vec![0.5]],
            vec![vec![0.1, 0.2, 0.3], vec![0.4]],
            vec![vec![0.01, 0.02, 0.03], vec![0.04]],
            7.0,
        )
    }

    #[test]
    fn encode_decode_roundtrip_is_bitwise() {
        let s = sample_snapshot(12);
        let back = Snapshot::decode(&s.encode(), Path::new("mem")).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.rng, Rng::for_step(42, 12).state());
    }

    #[test]
    fn cadence_fires_on_every_nth_completed_step() {
        let p = CheckpointPolicy::new("x", 5, 2);
        let due: Vec<u64> = (0..12).filter(|&s| p.should_save(s)).collect();
        assert_eq!(due, vec![4, 9]);
    }

    #[test]
    fn save_validates_moment_shapes() {
        let mut s = sample_snapshot(0);
        s.m.pop();
        let dir = std::env::temp_dir().join("pallas_ckpt_shape_test");
        let err = save(&dir, "t", &s).unwrap_err().to_string();
        assert!(err.contains("moment group sizes"), "{err}");
    }
}
