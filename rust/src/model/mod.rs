//! Pure-Rust reference GCN mirroring `python/compile/model.py`.
//!
//! Three roles:
//! 1. cross-validation oracle for the PJRT artifacts (golden tests),
//! 2. the rank-local compute kernel inside the 3D-PMM engine
//!    (which decomposes exactly these operators across the grid), and
//! 3. the full-graph distributed evaluation path (Table II), where the
//!    sparse N x N adjacency cannot be dense-ified for the artifacts.
//!
//! Forward: Eqs. 4-12; backward: Eqs. 13-19; Adam matches
//! `model.adam_update` bit-for-bit in structure (f32 arithmetic).

use crate::graph::Csr;
use crate::tensor::{log_softmax, rmsnorm, Mat};
use crate::util::rng::Rng;

pub const RMS_EPS: f32 = 1e-6;
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// Model dimensions (mirrors `ModelConfig` minus the fixed batch).
#[derive(Clone, Copy, Debug)]
pub struct GcnDims {
    pub d_in: usize,
    pub d_h: usize,
    pub d_out: usize,
    pub layers: usize,
    pub dropout: f32,
    pub weight_decay: f32,
}

impl GcnDims {
    pub fn n_params(&self) -> usize {
        2 + 2 * self.layers
    }

    /// Parameter shapes in artifact order: w_in, (w_l, g_l)*, w_out.
    /// RMSNorm scales are carried as 1 x d_h matrices.
    pub fn param_shapes(&self) -> Vec<(usize, usize)> {
        let mut s = vec![(self.d_in, self.d_h)];
        for _ in 0..self.layers {
            s.push((self.d_h, self.d_h));
            s.push((1, self.d_h));
        }
        s.push((self.d_h, self.d_out));
        s
    }
}

/// Flat parameter vector in artifact order.
pub type Params = Vec<Mat>;

/// Glorot weights, unit scales (same scheme as python init, independent
/// stream).
pub fn init_params(dims: &GcnDims, seed: u64) -> Params {
    let mut rng = Rng::new(seed ^ 0x9A7A);
    dims.param_shapes()
        .into_iter()
        .map(|(r, c)| {
            if r == 1 && c == dims.d_h {
                Mat::filled(r, c, 1.0)
            } else {
                Mat::glorot(r, c, &mut rng)
            }
        })
        .collect()
}

/// Per-layer forward cache for the backward pass.
pub struct LayerCache {
    pub h_in: Mat,
    pub h_agg: Mat,
    pub xc: Mat,
    pub inv_rms: Vec<f32>,
    pub mask: Mat,
}

pub struct ForwardCache {
    pub x: Mat,
    pub h0: Mat,
    pub layers: Vec<LayerCache>,
    pub h_last: Mat,
}

/// Dropout keep-masks scaled by 1/(1-p); `None` at eval time.
pub fn dropout_masks(dims: &GcnDims, rows: usize, rng: &mut Rng) -> Vec<Mat> {
    let keep = 1.0 - dims.dropout;
    (0..dims.layers)
        .map(|_| {
            let mut m = Mat::zeros(rows, dims.d_h);
            for v in m.data.iter_mut() {
                if rng.f32() < keep {
                    *v = 1.0 / keep;
                }
            }
            m
        })
        .collect()
}

/// Forward pass over an arbitrary (sparse) adjacency; `masks` omitted means
/// eval mode (dropout off).
pub fn forward(
    dims: &GcnDims,
    params: &Params,
    adj: &Csr,
    x: &Mat,
    masks: Option<&[Mat]>,
) -> (Mat, ForwardCache) {
    let rows = x.rows;
    let h0 = x.matmul(&params[0]); // Eq. 4
    let mut h = h0.clone();
    let mut layer_caches = Vec::with_capacity(dims.layers);
    for l in 0..dims.layers {
        let w = &params[1 + 2 * l];
        let g = &params[2 + 2 * l];
        let h_agg = adj.spmm(&h); // Eq. 5
        let xc = h_agg.matmul(w); // Eq. 6
        let (xn_scaled, inv_rms) = rmsnorm(&xc, g.row(0), RMS_EPS); // Eq. 7
        let y = xn_scaled.relu(); // Eq. 8
        let mask = match masks {
            Some(ms) => ms[l].clone(),
            None => Mat::filled(rows, dims.d_h, 1.0),
        };
        let yd = y.hadamard(&mask); // Eq. 9
        let h_next = yd.add(&h); // Eq. 10
        layer_caches.push(LayerCache { h_in: h, h_agg, xc, inv_rms, mask });
        h = h_next;
    }
    let logits = h.matmul(&params[dims.n_params() - 1]); // Eq. 11
    (
        logits,
        ForwardCache { x: x.clone(), h0, layers: layer_caches, h_last: h },
    )
}

/// Weighted cross-entropy + accuracy + logits gradient (Eq. 12 and the
/// start of the backward pass).
pub fn loss_and_grad(logits: &Mat, y: &[u32], w: &[f32]) -> (f32, f32, Mat) {
    let rows = logits.rows;
    assert_eq!(y.len(), rows);
    assert_eq!(w.len(), rows);
    let logp = log_softmax(logits);
    let denom: f32 = w.iter().sum::<f32>().max(1.0);
    let mut loss = 0.0f32;
    let mut correct = 0.0f32;
    let mut dlogits = Mat::zeros(rows, logits.cols);
    for i in 0..rows {
        let wi = w[i];
        let yi = y[i] as usize;
        let row = logp.row(i);
        if wi != 0.0 {
            loss += -row[yi] * wi;
            let arg = (0..logits.cols)
                .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                .unwrap();
            if arg == yi {
                correct += wi;
            }
        }
        let drow = &mut dlogits.data[i * logits.cols..(i + 1) * logits.cols];
        for j in 0..logits.cols {
            let softmax = row[j].exp();
            let onehot = if j == yi { 1.0 } else { 0.0 };
            drow[j] = wi * (softmax - onehot) / denom;
        }
    }
    (loss / denom, correct / denom, dlogits)
}

/// Backward pass (Eqs. 13-19); `adj_t` is the transposed adjacency.
pub fn backward(
    dims: &GcnDims,
    params: &Params,
    cache: &ForwardCache,
    adj_t: &Csr,
    dlogits: &Mat,
) -> Params {
    let np = dims.n_params();
    let mut grads: Params = dims
        .param_shapes()
        .into_iter()
        .map(|(r, c)| Mat::zeros(r, c))
        .collect();

    // output head (Eqs. 13-14)
    grads[np - 1] = cache.h_last.t_matmul(dlogits);
    let mut dh = dlogits.matmul_t(&params[np - 1]);

    for l in (0..dims.layers).rev() {
        let w = &params[1 + 2 * l];
        let g = &params[2 + 2 * l];
        let lc = &cache.layers[l];
        let rows = dh.rows;
        let dcols = dims.d_h;

        // element-wise backward: residual skip + dropout + relu + rmsnorm
        let mut dxc = Mat::zeros(rows, dcols);
        let mut dg = vec![0.0f32; dcols];
        for i in 0..rows {
            let inv = lc.inv_rms[i];
            let xc_row = lc.xc.row(i);
            let m_row = lc.mask.row(i);
            let dh_row = dh.row(i);
            // dy0 = dh * mask * relu'(xn*g); xn = xc*inv
            // then dxn = dy0 * g; dg += dy0 * xn
            let mut dot = 0.0f32; // mean(dxn * xc)
            let mut dxn_row = vec![0.0f32; dcols];
            for j in 0..dcols {
                let xn = xc_row[j] * inv;
                let y0 = xn * g.row(0)[j];
                let dy0 = if y0 > 0.0 { dh_row[j] * m_row[j] } else { 0.0 };
                dg[j] += dy0 * xn;
                let dxn = dy0 * g.row(0)[j];
                dxn_row[j] = dxn;
                dot += dxn * xc_row[j];
            }
            dot /= dcols as f32;
            let dxc_row = &mut dxc.data[i * dcols..(i + 1) * dcols];
            for j in 0..dcols {
                dxc_row[j] = inv * (dxn_row[j] - xc_row[j] * dot * inv * inv);
            }
        }
        grads[2 + 2 * l] = Mat::from_vec(1, dcols, dg);

        // GEMM backward (Eqs. 15-16)
        grads[1 + 2 * l] = lc.h_agg.t_matmul(&dxc);
        let dh_agg = dxc.matmul_t(w);

        // SpMM backward (Eq. 17) + residual merge
        let dh_conv = adj_t.spmm(&dh_agg);
        dh = dh_conv.add(&dh); // skip path carries dh unchanged
    }

    // input projection (Eqs. 18-19)
    grads[0] = cache.x.t_matmul(&dh);
    grads
}

/// Adam optimizer state.
#[derive(Clone)]
pub struct AdamState {
    pub m: Params,
    pub v: Params,
    pub t: f32,
}

impl AdamState {
    pub fn new(dims: &GcnDims) -> AdamState {
        let zeros: Params = dims
            .param_shapes()
            .into_iter()
            .map(|(r, c)| Mat::zeros(r, c))
            .collect();
        AdamState { m: zeros.clone(), v: zeros, t: 0.0 }
    }

    /// Bias-corrected Adam + decoupled weight decay, matching
    /// `model.adam_update`.
    pub fn update(&mut self, dims: &GcnDims, params: &mut Params, grads: &Params, lr: f32) {
        self.t += 1.0;
        let b1t = 1.0 - ADAM_B1.powf(self.t);
        let b2t = 1.0 - ADAM_B2.powf(self.t);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            for k in 0..p.data.len() {
                m.data[k] = ADAM_B1 * m.data[k] + (1.0 - ADAM_B1) * g.data[k];
                v.data[k] = ADAM_B2 * v.data[k] + (1.0 - ADAM_B2) * g.data[k] * g.data[k];
                let mut step = lr * (m.data[k] / b1t) / ((v.data[k] / b2t).sqrt() + ADAM_EPS);
                if dims.weight_decay > 0.0 {
                    step += lr * dims.weight_decay * p.data[k];
                }
                p.data[k] -= step;
            }
        }
    }
}

/// One full reference training step (sample-side inputs already prepared).
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    dims: &GcnDims,
    params: &mut Params,
    opt: &mut AdamState,
    adj: &Csr,
    adj_t: &Csr,
    x: &Mat,
    y: &[u32],
    w: &[f32],
    masks: &[Mat],
    lr: f32,
) -> (f32, f32) {
    let (logits, cache) = forward(dims, params, adj, x, Some(masks));
    let (loss, acc, dlogits) = loss_and_grad(&logits, y, w);
    let grads = backward(dims, params, &cache, adj_t, &dlogits);
    opt.update(dims, params, &grads, lr);
    (loss, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::rmat;

    fn dims() -> GcnDims {
        GcnDims { d_in: 6, d_h: 8, d_out: 3, layers: 2, dropout: 0.0, weight_decay: 0.0 }
    }

    fn setup(b: usize) -> (Csr, Csr, Mat, Vec<u32>, Vec<f32>) {
        let g = rmat(5, 4, 7).gcn_normalize();
        let s: Vec<u32> = (0..b as u32).collect();
        let mb = crate::sampling::induce_rescaled(&g, &s, 0.5);
        let mut rng = Rng::new(3);
        let x = Mat::randn(b, 6, &mut rng, 1.0);
        let y: Vec<u32> = (0..b).map(|i| (i % 3) as u32).collect();
        let w = vec![1.0f32; b];
        (mb.adj, mb.adj_t, x, y, w)
    }

    #[test]
    fn forward_shapes() {
        let d = dims();
        let p = init_params(&d, 0);
        let (adj, _, x, _, _) = setup(16);
        let (logits, cache) = forward(&d, &p, &adj, &x, None);
        assert_eq!((logits.rows, logits.cols), (16, 3));
        assert_eq!(cache.layers.len(), 2);
    }

    #[test]
    fn loss_grad_is_softmax_minus_onehot() {
        let logits = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let (loss, acc, d) = loss_and_grad(&logits, &[2], &[1.0]);
        assert!(loss > 0.0);
        assert_eq!(acc, 1.0);
        let sum: f32 = d.data.iter().sum();
        assert!(sum.abs() < 1e-6, "gradient rows sum to 0");
        assert!(d.data[2] < 0.0 && d.data[0] > 0.0);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let d = dims();
        let mut params = init_params(&d, 1);
        let (adj, adj_t, x, y, w) = setup(12);
        let (logits, cache) = forward(&d, &params, &adj, &x, None);
        let (_, _, dlogits) = loss_and_grad(&logits, &y, &w);
        let grads = backward(&d, &params, &cache, &adj_t, &dlogits);

        let loss_of = |params: &Params| -> f64 {
            let (lg, _) = forward(&d, params, &adj, &x, None);
            let (l, _, _) = loss_and_grad(&lg, &y, &w);
            l as f64
        };

        let eps = 1e-3f32;
        // probe a handful of coordinates in every parameter tensor
        for (pi, g) in grads.iter().enumerate() {
            let probes = [0usize, g.data.len() / 2, g.data.len() - 1];
            for &k in &probes {
                let orig = params[pi].data[k];
                params[pi].data[k] = orig + eps;
                let lp = loss_of(&params);
                params[pi].data[k] = orig - eps;
                let lm = loss_of(&params);
                params[pi].data[k] = orig;
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = g.data[k];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                    "param {pi} elem {k}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn training_reduces_loss() {
        let d = dims();
        let mut params = init_params(&d, 2);
        let mut opt = AdamState::new(&d);
        let (adj, adj_t, x, y, w) = setup(16);
        let masks = vec![Mat::filled(16, 8, 1.0); 2];
        let mut losses = vec![];
        for _ in 0..30 {
            let (l, _) =
                train_step(&d, &mut params, &mut opt, &adj, &adj_t, &x, &y, &w, &masks, 5e-3);
            losses.push(l);
        }
        assert!(losses[29] < losses[0] * 0.6, "{:?}", &losses[..5]);
    }

    #[test]
    fn dropout_masks_have_expected_density() {
        let d = GcnDims { dropout: 0.5, ..dims() };
        let mut rng = Rng::new(5);
        let ms = dropout_masks(&d, 100, &mut rng);
        assert_eq!(ms.len(), 2);
        let nz = ms[0].data.iter().filter(|&&v| v > 0.0).count();
        let frac = nz as f64 / ms[0].data.len() as f64;
        assert!((frac - 0.5).abs() < 0.08, "{frac}");
        // kept entries are scaled by 1/keep
        assert!(ms[0].data.iter().all(|&v| v == 0.0 || (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn adam_bias_correction_first_step() {
        let d = GcnDims { layers: 0, d_in: 1, d_h: 1, d_out: 1, dropout: 0.0, weight_decay: 0.0 };
        let mut params = vec![Mat::filled(1, 1, 1.0), Mat::filled(1, 1, 1.0)];
        let grads = vec![Mat::filled(1, 1, 0.5), Mat::filled(1, 1, 0.5)];
        let mut opt = AdamState::new(&d);
        opt.update(&d, &mut params, &grads, 0.1);
        // bias-corrected first step is ~lr * sign(g)
        assert!((params[0].data[0] - (1.0 - 0.1)).abs() < 1e-4);
    }

    #[test]
    fn eval_is_deterministic_without_masks() {
        let d = dims();
        let p = init_params(&d, 3);
        let (adj, _, x, _, _) = setup(10);
        let (l1, _) = forward(&d, &p, &adj, &x, None);
        let (l2, _) = forward(&d, &p, &adj, &x, None);
        assert_eq!(l1.data, l2.data);
    }
}
