//! Pure-Rust reference GCN mirroring `python/compile/model.py`.
//!
//! Three roles:
//! 1. cross-validation oracle for the PJRT artifacts (golden tests),
//! 2. the rank-local compute kernel inside the 3D-PMM engine
//!    (which decomposes exactly these operators across the grid), and
//! 3. the full-graph distributed evaluation path (Table II), where the
//!    sparse N x N adjacency cannot be dense-ified for the artifacts.
//!
//! Forward: Eqs. 4-12; backward: Eqs. 13-19; Adam matches
//! `model.adam_update` bit-for-bit in structure (f32 arithmetic).
//!
//! The hot path is the workspace API (`StepWorkspace`, `train_step_ws`):
//! forward/backward write into preallocated buffers, the per-layer
//! aggregate+transform runs through the fused `Csr::spmm_matmul_into`
//! kernel, and the input `x` and dropout masks are *borrowed*, never
//! cloned — a steady-state training step performs no heap allocation on
//! the serial path.  The original allocating `forward`/`backward`/
//! `train_step` entry points are kept as thin wrappers.

use crate::graph::Csr;
use crate::tensor::{matmul_into, matmul_t_into, rmsnorm_into, t_matmul_into, Mat};
use crate::util::rng::Rng;

/// RMSNorm variance epsilon (Eq. 7).
pub const RMS_EPS: f32 = 1e-6;
/// Adam first-moment decay β₁.
pub const ADAM_B1: f32 = 0.9;
/// Adam second-moment decay β₂.
pub const ADAM_B2: f32 = 0.999;
/// Adam denominator epsilon.
pub const ADAM_EPS: f32 = 1e-8;

/// Model dimensions (mirrors `ModelConfig` minus the fixed batch).
#[derive(Clone, Copy, Debug)]
pub struct GcnDims {
    /// Input feature dimensionality.
    pub d_in: usize,
    /// Hidden width.
    pub d_h: usize,
    /// Output classes.
    pub d_out: usize,
    /// Number of GCN layers.
    pub layers: usize,
    /// Dropout probability (0 disables).
    pub dropout: f32,
    /// Decoupled weight-decay coefficient (0 disables).
    pub weight_decay: f32,
}

impl GcnDims {
    /// Number of parameter tensors: `w_in`, per-layer `(w_l, g_l)`, `w_out`.
    pub fn n_params(&self) -> usize {
        2 + 2 * self.layers
    }

    /// Parameter shapes in artifact order: w_in, (w_l, g_l)*, w_out.
    /// RMSNorm scales are carried as 1 x d_h matrices.
    pub fn param_shapes(&self) -> Vec<(usize, usize)> {
        let mut s = vec![(self.d_in, self.d_h)];
        for _ in 0..self.layers {
            s.push((self.d_h, self.d_h));
            s.push((1, self.d_h));
        }
        s.push((self.d_h, self.d_out));
        s
    }

    /// Order-sensitive fold of every dimension field, one ingredient of
    /// the checkpoint `spec_hash` (resume refuses a snapshot whose model
    /// shape differs from the live run's).
    pub fn state_signature(&self) -> u64 {
        crate::checkpoint::state_hash(&[
            self.d_in as u64,
            self.d_h as u64,
            self.d_out as u64,
            self.layers as u64,
            self.dropout.to_bits() as u64,
            self.weight_decay.to_bits() as u64,
        ])
    }
}

/// Flat parameter vector in artifact order.
pub type Params = Vec<Mat>;

/// Glorot weights, unit scales (same scheme as python init, independent
/// stream).
pub fn init_params(dims: &GcnDims, seed: u64) -> Params {
    let mut rng = Rng::new(seed ^ 0x9A7A);
    dims.param_shapes()
        .into_iter()
        .map(|(r, c)| {
            if r == 1 && c == dims.d_h {
                Mat::filled(r, c, 1.0)
            } else {
                Mat::glorot(r, c, &mut rng)
            }
        })
        .collect()
}

/// Per-layer forward cache for the backward pass.  Only what backward
/// actually reads is kept; the layer input and dropout mask are *not*
/// cloned here (the mask is an input and is passed to `backward` again).
#[derive(Default)]
pub struct LayerCache {
    /// Aggregated features `adj @ h` (Eq. 5), kept for Eq. 15.
    pub h_agg: Mat,
    /// Pre-norm combined features `h_agg @ w` (Eq. 6), kept for Eq. 13.
    pub xc: Mat,
    /// Per-row inverse RMS of `xc` (RMSNorm backward).
    pub inv_rms: Vec<f32>,
}

/// Everything the backward pass reads from the forward pass.
#[derive(Default)]
pub struct ForwardCache {
    /// Per-layer caches, input-to-output order.
    pub layers: Vec<LayerCache>,
    /// Final hidden activation (the output head's input).
    pub h_last: Mat,
}

/// Dropout keep-masks scaled by 1/(1-p); `None` at eval time.
pub fn dropout_masks(dims: &GcnDims, rows: usize, rng: &mut Rng) -> Vec<Mat> {
    let keep = 1.0 - dims.dropout;
    (0..dims.layers)
        .map(|_| {
            let mut m = Mat::zeros(rows, dims.d_h);
            for v in m.data.iter_mut() {
                if rng.f32() < keep {
                    *v = 1.0 / keep;
                }
            }
            m
        })
        .collect()
}

/// Backward-pass scratch buffers, reused across steps.
#[derive(Default)]
struct BackwardScratch {
    dh: Mat,
    dxc: Mat,
    dh_agg: Mat,
    dh_conv: Mat,
    dxn_row: Vec<f32>,
}

/// Preallocated forward/backward buffers for the zero-allocation training
/// step.  Sized lazily on first use; reusable across steps and across
/// mini-batches of the same shape (reshaping reuses the allocations).
#[derive(Default)]
pub struct StepWorkspace {
    /// Forward-pass tensors the backward pass reads.
    pub cache: ForwardCache,
    /// Output-head logits of the last `forward_ws` call.
    pub logits: Mat,
    /// Loss gradient w.r.t. the logits.
    pub dlogits: Mat,
    /// Parameter gradients of the last `backward_ws` call.
    pub grads: Params,
    act: Mat,
    bwd: BackwardScratch,
}

impl StepWorkspace {
    /// Empty workspace; buffers are sized lazily on first use.
    pub fn new() -> StepWorkspace {
        StepWorkspace::default()
    }
}

/// Workspace forward pass over an arbitrary (sparse) adjacency; `masks`
/// omitted means eval mode (dropout off).  Logits land in `ws.logits`,
/// the backward inputs in `ws.cache`.  Per layer the aggregate (Eq. 5) and
/// transform (Eq. 6) run through the fused SpMM+GEMM kernel.
pub fn forward_ws(
    dims: &GcnDims,
    params: &Params,
    adj: &Csr,
    x: &Mat,
    masks: Option<&[Mat]>,
    ws: &mut StepWorkspace,
) {
    let rows = x.rows;
    let dh = dims.d_h;
    if let Some(ms) = masks {
        assert_eq!(ms.len(), dims.layers, "one dropout mask per layer");
    }
    while ws.cache.layers.len() < dims.layers {
        ws.cache.layers.push(LayerCache::default());
    }
    ws.cache.layers.truncate(dims.layers);

    let StepWorkspace { cache, logits, act, .. } = ws;
    let ForwardCache { layers, h_last } = cache;

    // input projection (Eq. 4): h = x @ w_in
    h_last.reset_for_overwrite(rows, dh);
    matmul_into(x, &params[0], h_last, false);

    for (l, lc) in layers.iter_mut().enumerate() {
        let w = &params[1 + 2 * l];
        let g = &params[2 + 2 * l];
        lc.h_agg.reset_for_overwrite(rows, dh);
        lc.xc.reset_for_overwrite(rows, dh);
        // fused Eq. 5 + Eq. 6: xc = (adj @ h) @ w, keeping the aggregate
        adj.spmm_matmul_into(h_last, w, Some(&mut lc.h_agg), &mut lc.xc);
        // RMSNorm (Eq. 7)
        lc.inv_rms.resize(rows, 0.0);
        act.reset_for_overwrite(rows, dh);
        rmsnorm_into(&lc.xc, g.row(0), RMS_EPS, act, &mut lc.inv_rms);
        // ReLU (Eq. 8) + dropout (Eq. 9) + residual (Eq. 10), fused
        // element-wise into the rolling h buffer
        match masks {
            Some(ms) => {
                let m = &ms[l];
                assert_eq!((m.rows, m.cols), (rows, dh), "mask shape");
                for ((h, &a), &mv) in
                    h_last.data.iter_mut().zip(&act.data).zip(&m.data)
                {
                    *h += a.max(0.0) * mv;
                }
            }
            None => {
                for (h, &a) in h_last.data.iter_mut().zip(&act.data) {
                    *h += a.max(0.0);
                }
            }
        }
    }

    // output head (Eq. 11)
    logits.reset_for_overwrite(rows, dims.d_out);
    matmul_into(h_last, &params[dims.n_params() - 1], logits, false);
}

/// Forward pass (allocating wrapper kept for oracles and tests); returns
/// `(logits, cache)`.  The input `x` is only borrowed.
pub fn forward(
    dims: &GcnDims,
    params: &Params,
    adj: &Csr,
    x: &Mat,
    masks: Option<&[Mat]>,
) -> (Mat, ForwardCache) {
    let mut ws = StepWorkspace::new();
    forward_ws(dims, params, adj, x, masks, &mut ws);
    (ws.logits, ws.cache)
}

/// Weighted cross-entropy + accuracy into a caller-provided gradient
/// buffer (Eq. 12 and the start of the backward pass); no allocation.
pub fn loss_and_grad_into(
    logits: &Mat,
    y: &[u32],
    w: &[f32],
    dlogits: &mut Mat,
) -> (f32, f32) {
    let rows = logits.rows;
    let cols = logits.cols;
    assert_eq!(y.len(), rows);
    assert_eq!(w.len(), rows);
    dlogits.reset_for_overwrite(rows, cols);
    let denom: f32 = w.iter().sum::<f32>().max(1.0);
    let mut loss = 0.0f32;
    let mut correct = 0.0f32;
    for i in 0..rows {
        let wi = w[i];
        let yi = y[i] as usize;
        let row = logits.row(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
        if wi != 0.0 {
            loss += -(row[yi] - lse) * wi;
            let arg = (0..cols)
                .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                .unwrap();
            if arg == yi {
                correct += wi;
            }
        }
        let drow = &mut dlogits.data[i * cols..(i + 1) * cols];
        for j in 0..cols {
            let softmax = (row[j] - lse).exp();
            let onehot = if j == yi { 1.0 } else { 0.0 };
            drow[j] = wi * (softmax - onehot) / denom;
        }
    }
    (loss / denom, correct / denom)
}

/// Weighted cross-entropy + accuracy + logits gradient (allocating
/// wrapper).
pub fn loss_and_grad(logits: &Mat, y: &[u32], w: &[f32]) -> (f32, f32, Mat) {
    let mut dlogits = Mat::zeros(logits.rows, logits.cols);
    let (loss, acc) = loss_and_grad_into(logits, y, w, &mut dlogits);
    (loss, acc, dlogits)
}

/// Workspace backward pass (Eqs. 13-19): gradients land in `ws.grads`.
/// `adj_t` is the transposed adjacency; `x` and `masks` are the same
/// borrowed inputs that were passed to `forward_ws` (the cache no longer
/// stores copies of either).
pub fn backward_ws(
    dims: &GcnDims,
    params: &Params,
    adj_t: &Csr,
    x: &Mat,
    masks: Option<&[Mat]>,
    ws: &mut StepWorkspace,
) {
    backward_ws_layered(dims, params, adj_t, x, masks, ws, |_, _| {});
}

/// As [`backward_ws`] but emitting each parameter gradient the moment it
/// is final (§V-D): `on_grad(param_index, grad)` fires for `w_out` first,
/// then per layer (output to input) `g_l` and `w_l`, and finally `w_in` —
/// so a distributed caller can issue the gradient's all-reduce bucket
/// while the remaining layers are still back-propagating.  Gradients also
/// land in `ws.grads` as usual.  (The PJRT `dp > 1` trainer receives its
/// gradients from the AOT artifact all at once and buckets at that
/// boundary instead; this hook is the pure-Rust counterpart for callers
/// that run `backward_ws` themselves, e.g. a future distributed
/// out-of-core path — the emission order is pinned by a unit test.)
pub fn backward_ws_layered(
    dims: &GcnDims,
    params: &Params,
    adj_t: &Csr,
    x: &Mat,
    masks: Option<&[Mat]>,
    ws: &mut StepWorkspace,
    mut on_grad: impl FnMut(usize, &Mat),
) {
    let np = dims.n_params();
    assert_eq!(params.len(), np);
    while ws.grads.len() < np {
        ws.grads.push(Mat::default());
    }
    ws.grads.truncate(np);

    let StepWorkspace { cache, dlogits, grads, bwd, .. } = ws;
    // gradient shapes mirror the parameters; sizing from them keeps the
    // steady-state step allocation-free (no shape-vector rebuild).  These
    // use the zeroing reset: the RMSNorm scale gradients accumulate with
    // `+=` and must start from zero.
    for (g, p) in grads.iter_mut().zip(params.iter()) {
        g.reset(p.rows, p.cols);
    }

    let rows = dlogits.rows;
    let dcols = dims.d_h;

    // output head (Eqs. 13-14)
    t_matmul_into(&cache.h_last, dlogits, &mut grads[np - 1]);
    on_grad(np - 1, &grads[np - 1]);
    bwd.dh.reset_for_overwrite(rows, dcols);
    matmul_t_into(dlogits, &params[np - 1], &mut bwd.dh);

    for l in (0..dims.layers).rev() {
        let w = &params[1 + 2 * l];
        let g = &params[2 + 2 * l];
        let lc = &cache.layers[l];

        // element-wise backward: residual skip + dropout + relu + rmsnorm
        bwd.dxc.reset_for_overwrite(rows, dcols);
        bwd.dxn_row.resize(dcols, 0.0);
        let dg = &mut grads[2 + 2 * l];
        for i in 0..rows {
            let inv = lc.inv_rms[i];
            let xc_row = lc.xc.row(i);
            let m_row = masks.map(|ms| ms[l].row(i));
            let dh_row = bwd.dh.row(i);
            // dy0 = dh * mask * relu'(xn*g); xn = xc*inv
            // then dxn = dy0 * g; dg += dy0 * xn
            let mut dot = 0.0f32; // mean(dxn * xc)
            for j in 0..dcols {
                let xn = xc_row[j] * inv;
                let y0 = xn * g.row(0)[j];
                let dy0 = if y0 > 0.0 {
                    match m_row {
                        Some(m) => dh_row[j] * m[j],
                        None => dh_row[j],
                    }
                } else {
                    0.0
                };
                dg.data[j] += dy0 * xn;
                let dxn = dy0 * g.row(0)[j];
                bwd.dxn_row[j] = dxn;
                dot += dxn * xc_row[j];
            }
            dot /= dcols as f32;
            let dxc_row = &mut bwd.dxc.data[i * dcols..(i + 1) * dcols];
            for j in 0..dcols {
                dxc_row[j] = inv * (bwd.dxn_row[j] - xc_row[j] * dot * inv * inv);
            }
        }

        // the scale gradient is final once every row accumulated (§V-D)
        on_grad(2 + 2 * l, &grads[2 + 2 * l]);

        // GEMM backward (Eqs. 15-16)
        t_matmul_into(&lc.h_agg, &bwd.dxc, &mut grads[1 + 2 * l]);
        on_grad(1 + 2 * l, &grads[1 + 2 * l]);
        bwd.dh_agg.reset_for_overwrite(rows, dcols);
        matmul_t_into(&bwd.dxc, w, &mut bwd.dh_agg);

        // SpMM backward (Eq. 17) + residual merge; skip path carries dh
        bwd.dh_conv.reset_for_overwrite(rows, dcols);
        adj_t.spmm_into(&bwd.dh_agg, &mut bwd.dh_conv);
        bwd.dh.add_assign(&bwd.dh_conv);
    }

    // input projection (Eqs. 18-19)
    t_matmul_into(x, &bwd.dh, &mut grads[0]);
    on_grad(0, &grads[0]);
}

/// Backward pass (allocating wrapper).  `adj_t` is the transposed
/// adjacency; `x`/`masks` are the forward inputs (borrowed, not cached).
pub fn backward(
    dims: &GcnDims,
    params: &Params,
    cache: ForwardCache,
    adj_t: &Csr,
    dlogits: &Mat,
    x: &Mat,
    masks: Option<&[Mat]>,
) -> Params {
    let mut ws = StepWorkspace {
        cache,
        dlogits: dlogits.clone(),
        ..StepWorkspace::default()
    };
    backward_ws(dims, params, adj_t, x, masks, &mut ws);
    ws.grads
}

/// Adam optimizer state.
#[derive(Clone)]
pub struct AdamState {
    /// First moments, one tensor per parameter.
    pub m: Params,
    /// Second moments, one tensor per parameter.
    pub v: Params,
    /// Step counter (f32 to mirror the artifact's scalar input).
    pub t: f32,
}

impl AdamState {
    /// Zero moments shaped like `dims.param_shapes()`.
    pub fn new(dims: &GcnDims) -> AdamState {
        let zeros: Params = dims
            .param_shapes()
            .into_iter()
            .map(|(r, c)| Mat::zeros(r, c))
            .collect();
        AdamState { m: zeros.clone(), v: zeros, t: 0.0 }
    }

    /// Bias-corrected Adam + decoupled weight decay, matching
    /// `model.adam_update`.
    pub fn update(&mut self, dims: &GcnDims, params: &mut Params, grads: &Params, lr: f32) {
        self.t += 1.0;
        let b1t = 1.0 - ADAM_B1.powf(self.t);
        let b2t = 1.0 - ADAM_B2.powf(self.t);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            for k in 0..p.data.len() {
                m.data[k] = ADAM_B1 * m.data[k] + (1.0 - ADAM_B1) * g.data[k];
                v.data[k] = ADAM_B2 * v.data[k] + (1.0 - ADAM_B2) * g.data[k] * g.data[k];
                let mut step = lr * (m.data[k] / b1t) / ((v.data[k] / b2t).sqrt() + ADAM_EPS);
                if dims.weight_decay > 0.0 {
                    step += lr * dims.weight_decay * p.data[k];
                }
                p.data[k] -= step;
            }
        }
    }
}

/// One full reference training step through the preallocated workspace:
/// fused forward, in-place loss gradient, workspace backward, Adam.  On
/// the serial path a steady-state call performs no heap allocation.
#[allow(clippy::too_many_arguments)]
pub fn train_step_ws(
    dims: &GcnDims,
    params: &mut Params,
    opt: &mut AdamState,
    adj: &Csr,
    adj_t: &Csr,
    x: &Mat,
    y: &[u32],
    w: &[f32],
    masks: &[Mat],
    lr: f32,
    ws: &mut StepWorkspace,
) -> (f32, f32) {
    forward_ws(dims, params, adj, x, Some(masks), ws);
    let StepWorkspace { logits, dlogits, .. } = ws;
    let (loss, acc) = loss_and_grad_into(logits, y, w, dlogits);
    backward_ws(dims, params, adj_t, x, Some(masks), ws);
    opt.update(dims, params, &ws.grads, lr);
    (loss, acc)
}

/// One full reference training step (allocating wrapper around
/// `train_step_ws` with a throwaway workspace).
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    dims: &GcnDims,
    params: &mut Params,
    opt: &mut AdamState,
    adj: &Csr,
    adj_t: &Csr,
    x: &Mat,
    y: &[u32],
    w: &[f32],
    masks: &[Mat],
    lr: f32,
) -> (f32, f32) {
    let mut ws = StepWorkspace::new();
    train_step_ws(dims, params, opt, adj, adj_t, x, y, w, masks, lr, &mut ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::rmat;

    fn dims() -> GcnDims {
        GcnDims { d_in: 6, d_h: 8, d_out: 3, layers: 2, dropout: 0.0, weight_decay: 0.0 }
    }

    fn setup(b: usize) -> (Csr, Csr, Mat, Vec<u32>, Vec<f32>) {
        let g = rmat(5, 4, 7).gcn_normalize();
        let s: Vec<u32> = (0..b as u32).collect();
        let mb = crate::sampling::induce_rescaled(&g, &s, 0.5);
        let mut rng = Rng::new(3);
        let x = Mat::randn(b, 6, &mut rng, 1.0);
        let y: Vec<u32> = (0..b).map(|i| (i % 3) as u32).collect();
        let w = vec![1.0f32; b];
        (mb.adj, mb.adj_t, x, y, w)
    }

    #[test]
    fn forward_shapes() {
        let d = dims();
        let p = init_params(&d, 0);
        let (adj, _, x, _, _) = setup(16);
        let (logits, cache) = forward(&d, &p, &adj, &x, None);
        assert_eq!((logits.rows, logits.cols), (16, 3));
        assert_eq!(cache.layers.len(), 2);
    }

    #[test]
    fn loss_grad_is_softmax_minus_onehot() {
        let logits = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let (loss, acc, d) = loss_and_grad(&logits, &[2], &[1.0]);
        assert!(loss > 0.0);
        assert_eq!(acc, 1.0);
        let sum: f32 = d.data.iter().sum();
        assert!(sum.abs() < 1e-6, "gradient rows sum to 0");
        assert!(d.data[2] < 0.0 && d.data[0] > 0.0);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let d = dims();
        let mut params = init_params(&d, 1);
        let (adj, adj_t, x, y, w) = setup(12);
        let (logits, cache) = forward(&d, &params, &adj, &x, None);
        let (_, _, dlogits) = loss_and_grad(&logits, &y, &w);
        let grads = backward(&d, &params, cache, &adj_t, &dlogits, &x, None);

        let loss_of = |params: &Params| -> f64 {
            let (lg, _) = forward(&d, params, &adj, &x, None);
            let (l, _, _) = loss_and_grad(&lg, &y, &w);
            l as f64
        };

        let eps = 1e-3f32;
        // probe a handful of coordinates in every parameter tensor
        for (pi, g) in grads.iter().enumerate() {
            let probes = [0usize, g.data.len() / 2, g.data.len() - 1];
            for &k in &probes {
                let orig = params[pi].data[k];
                params[pi].data[k] = orig + eps;
                let lp = loss_of(&params);
                params[pi].data[k] = orig - eps;
                let lm = loss_of(&params);
                params[pi].data[k] = orig;
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = g.data[k];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                    "param {pi} elem {k}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn training_reduces_loss() {
        let d = dims();
        let mut params = init_params(&d, 2);
        let mut opt = AdamState::new(&d);
        let (adj, adj_t, x, y, w) = setup(16);
        let masks = vec![Mat::filled(16, 8, 1.0); 2];
        let mut losses = vec![];
        for _ in 0..30 {
            let (l, _) =
                train_step(&d, &mut params, &mut opt, &adj, &adj_t, &x, &y, &w, &masks, 5e-3);
            losses.push(l);
        }
        assert!(losses[29] < losses[0] * 0.6, "{:?}", &losses[..5]);
    }

    #[test]
    fn workspace_step_matches_allocating_step_bitwise() {
        let d = dims();
        let (adj, adj_t, x, y, w) = setup(16);
        let masks = vec![Mat::filled(16, 8, 1.0); 2];

        let mut p1 = init_params(&d, 4);
        let mut o1 = AdamState::new(&d);
        let mut p2 = p1.clone();
        let mut o2 = o1.clone();
        let mut ws = StepWorkspace::new();
        for _ in 0..5 {
            let (l1, a1) =
                train_step(&d, &mut p1, &mut o1, &adj, &adj_t, &x, &y, &w, &masks, 5e-3);
            let (l2, a2) = train_step_ws(
                &d, &mut p2, &mut o2, &adj, &adj_t, &x, &y, &w, &masks, 5e-3, &mut ws,
            );
            assert_eq!(l1, l2);
            assert_eq!(a1, a2);
        }
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.data, b.data, "params diverged");
        }
    }

    #[test]
    fn workspace_is_reusable_across_batch_shapes() {
        let d = dims();
        let mut ws = StepWorkspace::new();
        for &b in &[16usize, 8, 24] {
            let (adj, adj_t, x, y, w) = setup(b);
            let mut params = init_params(&d, 5);
            let mut opt = AdamState::new(&d);
            let masks = vec![Mat::filled(b, 8, 1.0); 2];
            let (l, _) = train_step_ws(
                &d, &mut params, &mut opt, &adj, &adj_t, &x, &y, &w, &masks, 5e-3, &mut ws,
            );
            assert!(l.is_finite(), "b={b}");
            assert_eq!(ws.logits.rows, b);
        }
    }

    #[test]
    fn layered_backward_emits_final_grads_in_overlap_order() {
        let d = dims();
        let params = init_params(&d, 7);
        let (adj, adj_t, x, y, w) = setup(12);
        let (logits, cache) = forward(&d, &params, &adj, &x, None);
        let (_, _, dlogits) = loss_and_grad(&logits, &y, &w);
        let mut ws = StepWorkspace { cache, dlogits, ..StepWorkspace::default() };
        let mut order: Vec<(usize, Vec<f32>)> = vec![];
        backward_ws_layered(&d, &params, &adj_t, &x, None, &mut ws, |i, g| {
            order.push((i, g.data.clone()));
        });
        let np = d.n_params();
        // w_out first, then per layer (g_l, w_l) from the top, then w_in
        let mut want = vec![np - 1];
        for l in (0..d.layers).rev() {
            want.push(2 + 2 * l);
            want.push(1 + 2 * l);
        }
        want.push(0);
        assert_eq!(order.iter().map(|(i, _)| *i).collect::<Vec<_>>(), want);
        // every emitted gradient is bitwise the final one
        for (i, g) in &order {
            assert_eq!(ws.grads[*i].data, *g, "param {i}");
        }
    }

    #[test]
    fn dropout_masks_have_expected_density() {
        let d = GcnDims { dropout: 0.5, ..dims() };
        let mut rng = Rng::new(5);
        let ms = dropout_masks(&d, 100, &mut rng);
        assert_eq!(ms.len(), 2);
        let nz = ms[0].data.iter().filter(|&&v| v > 0.0).count();
        let frac = nz as f64 / ms[0].data.len() as f64;
        assert!((frac - 0.5).abs() < 0.08, "{frac}");
        // kept entries are scaled by 1/keep
        assert!(ms[0].data.iter().all(|&v| v == 0.0 || (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn adam_bias_correction_first_step() {
        let d = GcnDims { layers: 0, d_in: 1, d_h: 1, d_out: 1, dropout: 0.0, weight_decay: 0.0 };
        let mut params = vec![Mat::filled(1, 1, 1.0), Mat::filled(1, 1, 1.0)];
        let grads = vec![Mat::filled(1, 1, 0.5), Mat::filled(1, 1, 0.5)];
        let mut opt = AdamState::new(&d);
        opt.update(&d, &mut params, &grads, 0.1);
        // bias-corrected first step is ~lr * sign(g)
        assert!((params[0].data[0] - (1.0 - 0.1)).abs() < 1e-4);
    }

    #[test]
    fn eval_is_deterministic_without_masks() {
        let d = dims();
        let p = init_params(&d, 3);
        let (adj, _, x, _, _) = setup(10);
        let (l1, _) = forward(&d, &p, &adj, &x, None);
        let (l2, _) = forward(&d, &p, &adj, &x, None);
        assert_eq!(l1.data, l2.data);
    }
}
