//! Integration tests of the out-of-core binary graph store (`graph::store`):
//! pack→open bitwise round-trip, clean error paths on damaged containers,
//! same-seed bitwise equivalence of in-memory vs out-of-core mini-batches,
//! shard extraction through `GraphAccess`, and the end-to-end residency
//! guarantee: training from a store keeps resident graph+feature bytes
//! within the configured cache budget.

use std::path::PathBuf;
use std::sync::Arc;

use scalegnn::graph::store::{pack, GraphAccess, OocGraph, VertexData, BLOCK_BYTES};
use scalegnn::graph::{block_bounds, datasets, extract_shard_from, partition_2d};
use scalegnn::sampling::{induce_rescaled, induce_rescaled_from, UniformVertexSampler};
use scalegnn::trainer::batch::BatchMaker;
use scalegnn::trainer::{train_from_store, OocTrainConfig};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pallas_it_{name}_{}.pallas", std::process::id()))
}

/// Removes the backing file when the test ends (pass or fail).
struct TmpFile(PathBuf);

impl Drop for TmpFile {
    fn drop(&mut self) {
        std::fs::remove_file(&self.0).ok();
    }
}

#[test]
fn pack_open_roundtrip_is_bitwise() {
    let d = datasets::load("tiny").unwrap();
    let p = tmp("roundtrip");
    let _guard = TmpFile(p.clone());
    pack(&d, &p).unwrap();
    let g = OocGraph::open(&p, 4 << 20).unwrap();
    assert_eq!(g.n, d.n);
    assert_eq!(g.nnz, d.adj.nnz());
    assert_eq!(g.d_in, d.features.cols);
    assert_eq!(g.classes, d.classes);

    // adjacency: bitwise identical CSR
    assert_eq!(GraphAccess::rows(&g), d.n);
    assert_eq!(GraphAccess::row_nnz(&g, 0), d.adj.row_nnz(0));
    let csr = g.read_csr();
    assert_eq!(csr.indptr, d.adj.indptr);
    assert_eq!(csr.indices, d.adj.indices);
    assert_eq!(csr.values.len(), d.adj.values.len());
    for (a, b) in csr.values.iter().zip(&d.adj.values) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // features / labels / split, per vertex, through the cache
    let mut feat = vec![0.0f32; g.d_in];
    for v in 0..g.n {
        g.read_features(v, &mut feat);
        for (a, b) in feat.iter().zip(&d.features.data[v * g.d_in..(v + 1) * g.d_in]) {
            assert_eq!(a.to_bits(), b.to_bits(), "feature of vertex {v}");
        }
        assert_eq!(g.label_of(v), d.labels[v]);
        assert_eq!(g.split_of(v), d.split[v]);
    }
}

#[test]
fn truncated_and_corrupt_files_error_cleanly() {
    let d = datasets::load("tiny").unwrap();
    let p = tmp("corrupt");
    let _guard = TmpFile(p.clone());
    pack(&d, &p).unwrap();
    let full = std::fs::read(&p).unwrap();

    // truncated mid-file: open must fail with a clean error, not panic
    std::fs::write(&p, &full[..full.len() / 2]).unwrap();
    let e = OocGraph::open(&p, 1 << 20).unwrap_err();
    assert!(format!("{e:#}").contains("truncated"), "{e:#}");

    // shorter than the header
    std::fs::write(&p, &full[..10]).unwrap();
    assert!(OocGraph::open(&p, 1 << 20).is_err());

    // bad magic
    let mut bad = full.clone();
    bad[0] ^= 0xFF;
    std::fs::write(&p, &bad).unwrap();
    let e = OocGraph::open(&p, 1 << 20).unwrap_err();
    assert!(format!("{e:#}").contains("magic"), "{e:#}");

    // unsupported format version
    let mut bad = full.clone();
    bad[8] = 99;
    std::fs::write(&p, &bad).unwrap();
    let e = OocGraph::open(&p, 1 << 20).unwrap_err();
    assert!(format!("{e:#}").contains("version"), "{e:#}");

    // structurally corrupt indptr (correct length, non-monotone table):
    // open must reject it up front, not panic on a later row read
    let mut bad = full.clone();
    bad[64 + 15] = 0xFF; // high byte of indptr[1] -> indptr[2] < indptr[1]
    std::fs::write(&p, &bad).unwrap();
    let e = OocGraph::open(&p, 1 << 20).unwrap_err();
    assert!(format!("{e:#}").contains("indptr"), "{e:#}");

    // missing file
    assert!(OocGraph::open(&tmp("never_written"), 1 << 20).is_err());
}

#[test]
fn pack_is_atomic_and_leaves_no_tmp() {
    let d = datasets::load("tiny").unwrap();
    let p = tmp("atomic");
    let _guard = TmpFile(p.clone());
    pack(&d, &p).unwrap();
    let mut tmp_name = p.as_os_str().to_os_string();
    tmp_name.push(format!(".tmp.{}", std::process::id()));
    assert!(!std::path::Path::new(&tmp_name).exists(), "tmp sibling left behind");
    assert!(OocGraph::open(&p, 1 << 20).is_ok());
}

#[test]
fn same_seed_minibatches_are_bitwise_identical() {
    let d = Arc::new(datasets::load("tiny").unwrap());
    let p = tmp("equiv");
    let _guard = TmpFile(p.clone());
    pack(&d, &p).unwrap();
    let g = Arc::new(OocGraph::open(&p, 1 << 20).unwrap());

    // induced subgraphs: Csr oracle vs GraphAccess-on-store
    let sampler = UniformVertexSampler::new(d.n, 64, 7);
    for step in [0u64, 1, 9, 33] {
        let s = sampler.sample(step);
        let a = induce_rescaled(&d.adj, &s, sampler.inclusion_prob());
        let b = induce_rescaled_from(g.as_ref(), &s, sampler.inclusion_prob());
        assert_eq!(a.vertices, b.vertices, "step {step}");
        assert_eq!(a.adj.indptr, b.adj.indptr);
        assert_eq!(a.adj.indices, b.adj.indices);
        for (x, y) in a.adj.values.iter().zip(&b.adj.values) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    // full BatchMaker payloads (edges + features + labels + loss mask)
    let mut mm = BatchMaker::new(
        d.clone(),
        scalegnn::sampling::SamplerKind::ScaleGnnUniform,
        32,
        512,
        2,
        9,
    );
    let mut om = BatchMaker::from_store(g.clone(), 32, 512, 9);
    for step in 0..4u64 {
        let x = mm.make(step);
        let y = om.make(step);
        assert_eq!(x.src, y.src, "step {step}");
        assert_eq!(x.dst, y.dst);
        for (a, b) in x.val.iter().zip(&y.val) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in x.x.iter().zip(&y.x) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(x.y, y.y);
        assert_eq!(x.wmask, y.wmask);
        assert_eq!(x.truncated, y.truncated);
    }
}

#[test]
fn store_shards_match_in_memory_partition() {
    let d = datasets::load("tiny").unwrap();
    let p = tmp("shards");
    let _guard = TmpFile(p.clone());
    pack(&d, &p).unwrap();
    let g = OocGraph::open(&p, 1 << 20).unwrap();
    let want = partition_2d(&d.adj, 2, 3);
    let rb = block_bounds(d.n, 2);
    let cb = block_bounds(d.n, 3);
    let mut k = 0;
    for i in 0..2 {
        for j in 0..3 {
            let got = extract_shard_from(&g, rb[i], rb[i + 1], cb[j], cb[j + 1]);
            let w = &want[k];
            k += 1;
            assert_eq!((got.r0, got.r1, got.c0, got.c1), (w.r0, w.r1, w.c0, w.c1));
            assert_eq!(got.csr.cols, w.csr.cols);
            assert_eq!(got.csr.indptr, w.csr.indptr);
            assert_eq!(got.csr.indices, w.csr.indices);
            for (a, b) in got.csr.values.iter().zip(&w.csr.values) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}

#[test]
fn ooc_training_learns_within_cache_budget() {
    let p = tmp("train");
    let _guard = TmpFile(p.clone());
    let mut cfg = OocTrainConfig::quick(p.clone());
    cfg.dataset = Some("tiny".to_string()); // pack-once flow
    cfg.cache_bytes = BLOCK_BYTES; // a single resident block
    cfg.batch = 64;
    cfg.d_h = 16;
    cfg.layers = 2;
    cfg.steps = 60;
    cfg.lr = 5e-3;
    let r = train_from_store(&cfg).unwrap();
    assert_eq!(r.steps, 60);

    // residency guarantee: resident graph+feature bytes never exceed the
    // configured budget, and the store was never fully resident
    assert!(
        r.cache_resident_bytes <= r.cache_budget_bytes,
        "resident {} > budget {}",
        r.cache_resident_bytes,
        r.cache_budget_bytes
    );
    assert_eq!(r.cache_budget_bytes, BLOCK_BYTES);
    assert!(
        (r.cache_resident_bytes as u64) < r.store_bytes,
        "tiny store ({} B) should exceed one block",
        r.store_bytes
    );
    assert!(r.cache_misses > 0, "training must have touched the disk");

    // and it actually trains: loss falls over the run
    let head: f32 = r.loss_curve[..5].iter().map(|x| x.1).sum::<f32>() / 5.0;
    let tail: f32 =
        r.loss_curve[r.loss_curve.len() - 5..].iter().map(|x| x.1).sum::<f32>() / 5.0;
    assert!(r.final_loss.is_finite());
    assert!(tail < head, "loss did not fall: {head} -> {tail}");

    // prefetch off replays the identical deterministic trajectory
    let mut cfg2 = cfg.clone();
    cfg2.prefetch = false;
    cfg2.steps = 5;
    let r2 = train_from_store(&cfg2).unwrap();
    for (a, b) in r.loss_curve[..5].iter().zip(&r2.loss_curve) {
        assert_eq!(a.1, b.1, "prefetch changed the trajectory at step {}", a.0);
    }
}
