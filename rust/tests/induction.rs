//! Integration: the sampling fast path (sort-free workspace induction,
//! strategy-switching intersection, row-range parallelism) must be
//! **byte-identical** to the pre-fast-path reference
//! (`induce_rescaled_reference`: triple list -> sorting `from_triples` ->
//! allocating transpose) on every graph shape and batch regime — in
//! memory and out of core, serial and parallel, fresh and reused
//! workspace — and the `BatchData` the trainer consumes must be identical
//! through the whole maker pipeline.

use std::sync::Arc;

use scalegnn::graph::generate::rmat;
use scalegnn::graph::store::{pack, OocGraph};
use scalegnn::graph::{datasets, Csr};
use scalegnn::sampling::{
    induce_rescaled_into_threads, induce_rescaled_reference, InduceWorkspace, MiniBatch,
    SamplerKind, UniformVertexSampler,
};
use scalegnn::trainer::batch::BatchMaker;

const THREADS: &[usize] = &[1, 2, 3, 4, 8];

fn assert_minibatch_eq(got: &MiniBatch, want: &MiniBatch, what: &str) {
    assert_eq!(got.vertices, want.vertices, "{what}: vertices");
    assert_eq!((got.adj.rows, got.adj.cols), (want.adj.rows, want.adj.cols), "{what}: adj dims");
    assert_eq!(got.adj.indptr, want.adj.indptr, "{what}: adj indptr");
    assert_eq!(got.adj.indices, want.adj.indices, "{what}: adj indices");
    assert_eq!(got.adj.values, want.adj.values, "{what}: adj values");
    assert_eq!(got.adj_t.indptr, want.adj_t.indptr, "{what}: adj_t indptr");
    assert_eq!(got.adj_t.indices, want.adj_t.indices, "{what}: adj_t indices");
    assert_eq!(got.adj_t.values, want.adj_t.values, "{what}: adj_t values");
}

/// Fast path at every thread count — with a workspace reused across all of
/// them, the adversarial case — vs the reference oracle.
fn check_graph(g: &Csr, s: &[u32], p: f32, what: &str) {
    let want = induce_rescaled_reference(g, s, p);
    let mut ws = InduceWorkspace::new();
    let mut out = MiniBatch::default();
    for &t in THREADS {
        induce_rescaled_into_threads(g, s, p, true, t, &mut ws, &mut out);
        assert_minibatch_eq(&out, &want, &format!("{what} t={t}"));
    }
}

#[test]
fn fast_path_matches_reference_on_rmat_graphs() {
    for (scale, ef, seed) in [(8u32, 8usize, 1u64), (9, 16, 2), (10, 4, 3)] {
        let g = rmat(scale, ef, seed).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, (g.rows / 3).max(2), 7 + seed);
        for step in 0..4u64 {
            let s = sampler.sample(step);
            check_graph(&g, &s, sampler.inclusion_prob(), &format!("rmat s{scale} step {step}"));
        }
    }
}

#[test]
fn fast_path_matches_reference_on_full_batch() {
    // batch == n: every vertex sampled, p == 1 (no rescale)
    let g = rmat(8, 10, 11).gcn_normalize();
    let n = g.rows;
    let sampler = UniformVertexSampler::new(n, n, 5);
    let s = sampler.sample(0);
    assert_eq!(s.len(), n);
    assert_eq!(sampler.inclusion_prob(), 1.0);
    check_graph(&g, &s, sampler.inclusion_prob(), "batch == n");
}

#[test]
fn fast_path_matches_reference_on_batch_of_one() {
    // batch == 1: p == 0 by Eq. 23; only a self loop can survive and it is
    // never divided by p
    let g = rmat(7, 6, 13).gcn_normalize();
    let sampler = UniformVertexSampler::new(g.rows, 1, 17);
    for step in 0..6u64 {
        let s = sampler.sample(step);
        check_graph(&g, &s, sampler.inclusion_prob(), &format!("batch==1 step {step}"));
    }
}

#[test]
fn fast_path_matches_reference_on_empty_rows() {
    // raw un-normalized graph: many rows have no entries at all
    let n = 600usize;
    let mut triples = Vec::new();
    for i in (0..n).step_by(7) {
        triples.push((i as u32, ((i * 13 + 5) % n) as u32, 0.5));
    }
    let g = Csr::from_triples(n, n, triples);
    assert!(g.degrees().iter().filter(|&&d| d == 0).count() > n / 2);
    let sampler = UniformVertexSampler::new(n, 200, 3);
    let s = sampler.sample(1);
    check_graph(&g, &s, sampler.inclusion_prob(), "empty rows");
}

#[test]
fn fast_path_matches_reference_on_all_self_loop_graph() {
    // pure diagonal: every induced edge is a self loop (weights untouched)
    let n = 500usize;
    let triples: Vec<(u32, u32, f32)> =
        (0..n as u32).map(|i| (i, i, 1.0 + i as f32 * 0.01)).collect();
    let g = Csr::from_triples(n, n, triples);
    let sampler = UniformVertexSampler::new(n, 128, 23);
    let s = sampler.sample(2);
    check_graph(&g, &s, sampler.inclusion_prob(), "all self loops");
    let want = induce_rescaled_reference(&g, &s, sampler.inclusion_prob());
    assert_eq!(want.adj.nnz(), s.len(), "one self loop per sampled vertex");
}

#[test]
fn both_gallop_strategies_match_the_merge() {
    // Star graph: hub row 0 has degree n-1 (probe-the-row strategy when the
    // sample is small), leaves have degree 2 (merge / probe-the-sample).
    let n = 3000usize;
    let mut triples = Vec::new();
    for j in 1..n as u32 {
        triples.push((0u32, j, 0.25));
        triples.push((j, 0u32, 0.25));
        triples.push((j, j, 1.0));
    }
    let g = Csr::from_triples(n, n, triples);

    // small sample including the hub: hub row takes the probe-the-row
    // branch (deg = 2999 > 16 * B)
    let mut s: Vec<u32> = vec![0, 3, 50, 700, 1500, 2200, 2999];
    s.sort_unstable();
    check_graph(&g, &s, 0.3, "probe-the-row (hub, small sample)");

    // large sample over low-degree rows: probe-the-sample branch
    // (deg * 16 < B for every leaf row)
    let sampler = UniformVertexSampler::new(n, 1024, 31);
    let s = sampler.sample(4);
    check_graph(&g, &s, sampler.inclusion_prob(), "probe-the-sample (large batch)");
}

#[test]
fn skewed_rmat_exercises_mixed_strategies_bitwise() {
    // R-MAT degree profiles are heavy-tailed: with a large batch the same
    // induction mixes probe-the-sample rows (low-degree tail) and merge
    // rows (hubs) in one pass.
    let g = rmat(11, 16, 41).gcn_normalize();
    let degs = g.degrees();
    let dmax = *degs.iter().max().unwrap();
    let dmin = *degs.iter().min().unwrap();
    assert!(dmax > 4 * dmin.max(1), "expected a skewed degree profile ({dmin}..{dmax})");
    let sampler = UniformVertexSampler::new(g.rows, 1024, 43);
    for step in 0..3u64 {
        let s = sampler.sample(step);
        check_graph(&g, &s, sampler.inclusion_prob(), &format!("skewed rmat step {step}"));
    }
}

#[test]
fn sorted_triple_constructor_agrees_with_direct_assembly() {
    // Three independent routes to the induced adjacency must coincide:
    // the sorting `from_triples` (reference), the sort-free
    // `from_sorted_triples_into` over the same in-order triple stream,
    // and the fast path's direct segment assembly.
    let g = rmat(9, 12, 61).gcn_normalize();
    let sampler = UniformVertexSampler::new(g.rows, 160, 63);
    let mut sorted = Csr::empty(0, 0);
    let mut ws = InduceWorkspace::new();
    let mut fast = MiniBatch::default();
    for step in 0..4u64 {
        let s = sampler.sample(step);
        let p = sampler.inclusion_prob();
        let want = induce_rescaled_reference(&g, &s, p);
        // rebuild the reference's (row, col)-ordered, duplicate-free
        // triple stream and feed it to the sort-free constructor
        let mut triples: Vec<(u32, u32, f32)> = Vec::new();
        for r in 0..want.adj.rows {
            let (cs, vs) = want.adj.row(r);
            for (&c, &v) in cs.iter().zip(vs) {
                triples.push((r as u32, c, v));
            }
        }
        Csr::from_sorted_triples_into(s.len(), s.len(), &triples, &mut sorted);
        assert_eq!(sorted.indptr, want.adj.indptr, "step {step}");
        assert_eq!(sorted.indices, want.adj.indices);
        assert_eq!(sorted.values, want.adj.values);
        induce_rescaled_into_threads(&g, &s, p, true, 1, &mut ws, &mut fast);
        assert_eq!(fast.adj.indptr, sorted.indptr, "step {step}");
        assert_eq!(fast.adj.indices, sorted.indices);
        assert_eq!(fast.adj.values, sorted.values);
    }
}

#[test]
fn workspace_reuse_across_heterogeneous_calls_is_clean() {
    // one workspace serves alternating graphs/batch sizes without
    // cross-step contamination
    let g1 = rmat(9, 8, 51).gcn_normalize();
    let g2 = rmat(8, 24, 52).gcn_normalize();
    let mut ws = InduceWorkspace::new();
    let mut out = MiniBatch::default();
    for step in 0..6u64 {
        let (g, batch) = if step % 2 == 0 { (&g1, 300) } else { (&g2, 40) };
        let sampler = UniformVertexSampler::new(g.rows, batch, 60 + step);
        let s = sampler.sample(step);
        let p = sampler.inclusion_prob();
        let want = induce_rescaled_reference(g, &s, p);
        induce_rescaled_into_threads(g, &s, p, true, 4, &mut ws, &mut out);
        assert_minibatch_eq(&out, &want, &format!("heterogeneous step {step}"));
    }
}

/// The pre-fast-path `BatchMaker::make` pipeline, reconstructed verbatim:
/// reference induction + serial flatten + serial gather.
fn reference_batch(
    d: &scalegnn::graph::Dataset,
    sampler: &UniformVertexSampler,
    step: u64,
    edge_cap: usize,
) -> (Vec<i32>, Vec<i32>, Vec<f32>, Vec<f32>, Vec<i32>, Vec<f32>, usize) {
    let s = sampler.sample(step);
    let mb = induce_rescaled_reference(&d.adj, &s, sampler.inclusion_prob());
    let w: Vec<f32> = s
        .iter()
        .map(|&v| if d.split[v as usize] == 0 { 1.0 } else { 0.0 })
        .collect();
    let mut src = vec![0i32; edge_cap];
    let mut dst = vec![0i32; edge_cap];
    let mut val = vec![0.0f32; edge_cap];
    let mut k = 0usize;
    let mut truncated = 0usize;
    for r in 0..mb.adj.rows {
        let (cs, vs) = mb.adj.row(r);
        for (&c, &wv) in cs.iter().zip(vs) {
            if k < edge_cap {
                dst[k] = r as i32;
                src[k] = c as i32;
                val[k] = wv;
                k += 1;
            } else {
                truncated += 1;
            }
        }
    }
    let d_in = d.features.cols;
    let mut x = vec![0.0f32; s.len() * d_in];
    let mut y = vec![0i32; s.len()];
    for (i, &v) in s.iter().enumerate() {
        x[i * d_in..(i + 1) * d_in]
            .copy_from_slice(&d.features.data[v as usize * d_in..(v as usize + 1) * d_in]);
        y[i] = d.labels[v as usize] as i32;
    }
    (src, dst, val, x, y, w, truncated)
}

#[test]
fn batch_maker_matches_pre_fast_path_batches() {
    let d = Arc::new(datasets::load("tiny").unwrap());
    let seed = 9u64;
    let (batch, edge_cap) = (32usize, 512usize);
    let sampler = UniformVertexSampler::new(d.n, batch, seed);
    let mut maker =
        BatchMaker::new(d.clone(), SamplerKind::ScaleGnnUniform, batch, edge_cap, 2, seed);
    for step in 0..6u64 {
        let got = maker.make(step);
        let (src, dst, val, x, y, w, truncated) = reference_batch(&d, &sampler, step, edge_cap);
        assert_eq!(got.src, src, "step {step}");
        assert_eq!(got.dst, dst);
        assert_eq!(got.val, val);
        assert_eq!(got.x, x);
        assert_eq!(got.y, y);
        assert_eq!(got.wmask, w);
        assert_eq!(got.truncated, truncated);
        maker.recycle(got);
    }
}

#[test]
fn ooc_fast_path_matches_reference_and_memory() {
    let d = Arc::new(datasets::load("tiny").unwrap());
    let path = std::env::temp_dir().join("pallas_induction_test_tiny.pallas");
    pack(&d, &path).unwrap();
    let store = Arc::new(OocGraph::open(&path, 1 << 20).unwrap());

    // raw induction: OOC fast path == OOC reference == in-memory reference
    let sampler = UniformVertexSampler::new(d.n, 48, 77);
    let mut ws = InduceWorkspace::new();
    let mut out = MiniBatch::default();
    for step in 0..4u64 {
        let s = sampler.sample(step);
        let p = sampler.inclusion_prob();
        let want_mem = induce_rescaled_reference(&d.adj, &s, p);
        let want_ooc = induce_rescaled_reference(store.as_ref(), &s, p);
        assert_minibatch_eq(&want_ooc, &want_mem, &format!("ooc-vs-mem ref step {step}"));
        for &t in THREADS {
            induce_rescaled_into_threads(store.as_ref(), &s, p, true, t, &mut ws, &mut out);
            assert_minibatch_eq(&out, &want_mem, &format!("ooc fast step {step} t={t}"));
        }
    }

    // the full BatchData payload: OOC maker == in-memory maker, recycled
    let mut mem = BatchMaker::new(d.clone(), SamplerKind::ScaleGnnUniform, 32, 512, 2, 5);
    let mut ooc = BatchMaker::from_store(store, 32, 512, 5);
    for step in 0..4u64 {
        let a = mem.make(step);
        let b = ooc.make(step);
        assert_eq!(a.src, b.src, "step {step}");
        assert_eq!(a.dst, b.dst);
        assert_eq!(a.val, b.val);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        assert_eq!(a.wmask, b.wmask);
        mem.recycle(a);
        ooc.recycle(b);
    }
    let _ = std::fs::remove_file(&path);
}
