//! Integration: the parallel/tiled kernels must agree with the serial
//! reference on adversarial shapes — empty rows, single-column matrices,
//! fewer rows than threads, and dimensions that are not multiples of the
//! internal tile sizes.  Row-parallel paths are asserted **bitwise**
//! identical (they run the same per-element accumulation order); the fused
//! SpMM+GEMM path is additionally held to the ≤1e-6 relative-error bar.

use scalegnn::graph::Csr;
use scalegnn::tensor::{
    matmul_into_threads, matmul_t_into_threads, t_matmul_into_threads, Mat,
};
use scalegnn::util::rng::Rng;

const THREADS: [usize; 4] = [2, 3, 5, 8];

/// Shapes chosen to hit every boundary: 1 row, 1 col, rows < threads,
/// k/n straddling the 256-wide tile, and an empty-ish inner dim.
const SHAPES: [(usize, usize, usize); 8] = [
    (1, 1, 1),
    (1, 300, 1),
    (3, 7, 513), // n not a multiple of the j-tile
    (2, 1, 300),
    (7, 257, 255),
    (64, 64, 64),
    (129, 31, 258),
    (5, 128, 256),
];

fn rel_err(a: &Mat, b: &Mat) -> f32 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs() / (1.0 + y.abs()))
        .fold(0.0f32, f32::max)
}

#[test]
fn matmul_parallel_is_bitwise_serial_on_adversarial_shapes() {
    let mut rng = Rng::new(100);
    for &(m, k, n) in &SHAPES {
        let a = Mat::randn(m, k, &mut rng, 1.0);
        let b = Mat::randn(k, n, &mut rng, 1.0);
        let mut want = Mat::zeros(m, n);
        matmul_into_threads(&a, &b, &mut want, false, 1);
        for &t in &THREADS {
            let mut got = Mat::zeros(m, n);
            matmul_into_threads(&a, &b, &mut got, false, t);
            assert_eq!(got.data, want.data, "matmul {m}x{k}x{n} t={t}");
        }
    }
}

#[test]
fn transposed_matmuls_parallel_are_bitwise_serial() {
    let mut rng = Rng::new(101);
    for &(m, k, n) in &SHAPES {
        // t_matmul: A is k x m (contract over rows)
        let a = Mat::randn(k, m, &mut rng, 1.0);
        let b = Mat::randn(k, n, &mut rng, 1.0);
        let mut want = Mat::zeros(m, n);
        t_matmul_into_threads(&a, &b, &mut want, 1);
        for &t in &THREADS {
            let mut got = Mat::zeros(m, n);
            t_matmul_into_threads(&a, &b, &mut got, t);
            assert_eq!(got.data, want.data, "t_matmul {m}x{k}x{n} t={t}");
        }
        // matmul_t: B is n x k (contract over cols)
        let a2 = Mat::randn(m, k, &mut rng, 1.0);
        let b2 = Mat::randn(n, k, &mut rng, 1.0);
        let mut want2 = Mat::zeros(m, n);
        matmul_t_into_threads(&a2, &b2, &mut want2, 1);
        for &t in &THREADS {
            let mut got2 = Mat::zeros(m, n);
            matmul_t_into_threads(&a2, &b2, &mut got2, t);
            assert_eq!(got2.data, want2.data, "matmul_t {m}x{k}x{n} t={t}");
        }
    }
}

fn random_csr_with_empty_rows(rows: usize, cols: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut triples = vec![];
    for r in 0..rows {
        if r % 3 == 1 {
            continue; // every third row empty
        }
        let deg = (rng.next_u64() % 6) as usize;
        for _ in 0..deg {
            let c = (rng.next_u64() % cols as u64) as u32;
            triples.push((r as u32, c, rng.f32() + 0.1));
        }
    }
    Csr::from_triples(rows, cols, triples)
}

#[test]
fn spmm_parallel_is_bitwise_serial_with_empty_rows() {
    let mut rng = Rng::new(102);
    for &(rows, cols, d) in &[(1usize, 4usize, 1usize), (9, 5, 1), (257, 64, 3), (73, 128, 130)] {
        let a = random_csr_with_empty_rows(rows, cols, rows as u64);
        let x = Mat::randn(cols, d, &mut rng, 1.0);
        let mut want = Mat::zeros(rows, d);
        a.spmm_into_threads(&x, &mut want, 1);
        for &t in &THREADS {
            let mut got = Mat::zeros(rows, d);
            a.spmm_into_threads(&x, &mut got, t);
            assert_eq!(got.data, want.data, "spmm {rows}x{cols}x{d} t={t}");
        }
    }
}

#[test]
fn fused_spmm_matmul_is_bitwise_unfused_and_within_rel_err() {
    let mut rng = Rng::new(103);
    for &(rows, cols, d, p) in
        &[(1usize, 3usize, 2usize, 1usize), (50, 40, 1, 7), (257, 120, 33, 65), (16, 16, 300, 300)]
    {
        let a = random_csr_with_empty_rows(rows, cols, (rows + p) as u64);
        let x = Mat::randn(cols, d, &mut rng, 1.0);
        let w = Mat::randn(d, p, &mut rng, 1.0);
        let mut want_agg = Mat::zeros(rows, d);
        a.spmm_into_threads(&x, &mut want_agg, 1);
        let mut want = Mat::zeros(rows, p);
        matmul_into_threads(&want_agg, &w, &mut want, false, 1);
        for &t in &[1usize, 2, 4, 8] {
            let mut agg = Mat::zeros(rows, d);
            let mut got = Mat::zeros(rows, p);
            a.spmm_matmul_into_threads(&x, &w, Some(&mut agg), &mut got, t);
            assert_eq!(agg.data, want_agg.data, "fused agg {rows} t={t}");
            assert_eq!(got.data, want.data, "fused out {rows} t={t}");
            assert!(rel_err(&got, &want) <= 1e-6, "fused rel err {rows} t={t}");
            let mut got2 = Mat::zeros(rows, p);
            a.spmm_matmul_into_threads(&x, &w, None, &mut got2, t);
            assert_eq!(got2.data, want.data, "fused no-agg {rows} t={t}");
        }
    }
}

#[test]
fn rows_fewer_than_threads_still_complete() {
    let mut rng = Rng::new(104);
    let a = Mat::randn(2, 600, &mut rng, 1.0);
    let b = Mat::randn(600, 600, &mut rng, 1.0);
    let mut want = Mat::zeros(2, 600);
    matmul_into_threads(&a, &b, &mut want, false, 1);
    let mut got = Mat::zeros(2, 600);
    matmul_into_threads(&a, &b, &mut got, false, 64);
    assert_eq!(got.data, want.data);
}

#[test]
fn pallas_threads_env_selects_serial_fallback() {
    // spawn a fresh-env child check via the pool API contract instead of
    // mutating this process's environment (tests run in parallel)
    assert!(scalegnn::tensor::pool::num_threads() >= 1);
    // the explicit-thread API with t=1 is the documented serial fallback
    let mut rng = Rng::new(105);
    let a = Mat::randn(300, 64, &mut rng, 1.0);
    let b = Mat::randn(64, 64, &mut rng, 1.0);
    let mut s1 = Mat::zeros(300, 64);
    matmul_into_threads(&a, &b, &mut s1, false, 1);
    let mut s2 = Mat::zeros(300, 64);
    matmul_into_threads(&a, &b, &mut s2, false, 1);
    assert_eq!(s1.data, s2.data);
}
