//! Integration: the workspace training step must allocate dramatically
//! less than the allocating wrapper — the acceptance bar is ≥30% fewer
//! heap allocations per step; the steady-state serial workspace step is in
//! fact expected to allocate (near) zero.
//!
//! A counting global allocator measures exact allocation counts.  The test
//! pins `PALLAS_THREADS=1` before any kernel runs so the serial fallback is
//! exercised and thread-spawn allocations cannot pollute the counts (this
//! file contains exactly one test, so there is no env-mutation race).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn workspace_step_allocates_at_least_30_percent_less() {
    std::env::set_var("PALLAS_THREADS", "1");

    use scalegnn::graph::generate::rmat;
    use scalegnn::model::{
        init_params, train_step, train_step_ws, AdamState, GcnDims, StepWorkspace,
    };
    use scalegnn::tensor::Mat;
    use scalegnn::util::rng::Rng;

    let dims = GcnDims {
        d_in: 16,
        d_h: 32,
        d_out: 4,
        layers: 2,
        dropout: 0.0,
        weight_decay: 0.0,
    };
    let b = 64usize;
    let g = rmat(7, 8, 5).gcn_normalize();
    let s: Vec<u32> = (0..b as u32).collect();
    let mb = scalegnn::sampling::induce_rescaled(&g, &s, 0.5);
    let mut rng = Rng::new(1);
    let x = Mat::randn(b, dims.d_in, &mut rng, 1.0);
    let y: Vec<u32> = (0..b).map(|i| (i % 4) as u32).collect();
    let w = vec![1.0f32; b];
    let masks = vec![Mat::filled(b, dims.d_h, 1.0); dims.layers];

    // --- allocating wrapper baseline ---
    let mut p1 = init_params(&dims, 7);
    let mut o1 = AdamState::new(&dims);
    // warm up once so lazy statics / dataset caches don't skew either side
    train_step(&dims, &mut p1, &mut o1, &mb.adj, &mb.adj_t, &x, &y, &w, &masks, 1e-3);
    let before = allocs();
    for _ in 0..5 {
        train_step(&dims, &mut p1, &mut o1, &mb.adj, &mb.adj_t, &x, &y, &w, &masks, 1e-3);
    }
    let naive = allocs() - before;

    // --- workspace path ---
    let mut p2 = init_params(&dims, 7);
    let mut o2 = AdamState::new(&dims);
    let mut ws = StepWorkspace::new();
    // warm-up sizes the workspace buffers
    train_step_ws(&dims, &mut p2, &mut o2, &mb.adj, &mb.adj_t, &x, &y, &w, &masks, 1e-3, &mut ws);
    let before = allocs();
    for _ in 0..5 {
        train_step_ws(
            &dims, &mut p2, &mut o2, &mb.adj, &mb.adj_t, &x, &y, &w, &masks, 1e-3, &mut ws,
        );
    }
    let ws_allocs = allocs() - before;

    println!("allocations per 5 steps: allocating={naive} workspace={ws_allocs}");
    assert!(naive > 0, "baseline should allocate");
    // acceptance: >= 30% fewer allocations (in practice ~100%)
    assert!(
        (ws_allocs as f64) <= 0.7 * naive as f64,
        "workspace step allocates too much: {ws_allocs} vs naive {naive}"
    );
    // the steady-state serial workspace step is designed to be allocation-
    // free; allow a tiny slack for platform-dependent runtime internals
    assert!(
        ws_allocs <= 10,
        "workspace step expected ~0 allocations, got {ws_allocs} over 5 steps"
    );
}
