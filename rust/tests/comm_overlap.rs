//! Integration tests of the §V-D nonblocking chunked collective engine:
//! concurrent in-flight ops across axes, byte accounting under chunking,
//! the mismatch handshake (clean error, not a deadlock), and bitwise
//! equality of overlap-on vs overlap-off training trajectories.

use std::sync::Arc;

use scalegnn::comm::{CommWorld, Precision};
use scalegnn::graph::datasets;
use scalegnn::grid::{Axis, Grid4D};
use scalegnn::model::GcnDims;
use scalegnn::pmm::{PmmCtx, PmmGcn};
use scalegnn::tensor::Mat;

fn tiny_dims() -> GcnDims {
    GcnDims { d_in: 16, d_h: 16, d_out: 4, layers: 2, dropout: 0.3, weight_decay: 0.0 }
}

/// Run `steps` engine steps on every rank of `grid` with the given §V-D
/// overlap setting; returns per-rank (losses, gathered params).
fn run_engine_overlap(
    grid: Grid4D,
    overlap: bool,
    steps: u64,
    seed: u64,
) -> Vec<(Vec<f32>, Vec<Mat>)> {
    let data = Arc::new(datasets::load("tiny").unwrap());
    let dims = tiny_dims();
    let world = Arc::new(CommWorld::new(grid));
    let mut hs = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        hs.push(std::thread::spawn(move || {
            let ctx = PmmCtx::new(grid, r, &w, Precision::Fp32);
            let mut eng = PmmGcn::new(ctx, dims, 48, d, seed);
            eng.set_overlap(overlap);
            let mut losses = vec![];
            for s in 0..steps {
                losses.push(eng.train_step(s, 5e-3).loss);
            }
            (losses, eng.gather_params())
        }));
    }
    hs.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn overlap_on_and_off_are_bitwise_identical() {
    // DP=2 so the per-layer DP gradient buckets are exercised too
    for grid in [Grid4D::new(2, 2, 1, 1), Grid4D::new(1, 2, 2, 2)] {
        let on = run_engine_overlap(grid, true, 3, 42);
        let off = run_engine_overlap(grid, false, 3, 42);
        for (rank, (a, b)) in on.iter().zip(&off).enumerate() {
            assert_eq!(a.0, b.0, "grid {grid:?} rank {rank}: losses diverged");
            assert_eq!(a.1.len(), b.1.len());
            for (i, (pa, pb)) in a.1.iter().zip(&b.1).enumerate() {
                assert_eq!(pa.data, pb.data, "grid {grid:?} rank {rank} param {i}");
            }
        }
    }
}

#[test]
fn repeated_overlap_runs_are_bitwise_deterministic() {
    // arrival order must never leak into the sums (group-index-ordered
    // reduction), so two identical runs agree to the bit
    let grid = Grid4D::new(1, 2, 2, 1);
    let a = run_engine_overlap(grid, true, 3, 7);
    let b = run_engine_overlap(grid, true, 3, 7);
    for ((la, pa), (lb, pb)) in a.iter().zip(&b) {
        assert_eq!(la, lb);
        for (ma, mb) in pa.iter().zip(pb) {
            assert_eq!(ma.data, mb.data);
        }
    }
}

#[test]
fn concurrent_issue_stress_across_axes() {
    // many in-flight PendingOps per rank, spread over all four axes, with
    // tiny chunks so every op is multi-chunk; waits happen out of issue
    // order within an axis
    let grid = Grid4D::new(2, 2, 2, 1);
    let world = Arc::new(CommWorld::with_chunk_elems(grid, 16));
    let mut hs = vec![];
    for rank in 0..grid.world_size() {
        let w = world.clone();
        hs.push(std::thread::spawn(move || {
            let g = w.grid;
            let sum_of = |axis: Axis, f: &dyn Fn(usize) -> f32| -> f32 {
                g.group_ranks(rank, axis).into_iter().map(f).sum()
            };
            for round in 0..25u32 {
                let rb = round as f32;
                let vx = vec![rank as f32 + rb; 100];
                let vy = vec![2.0 * rank as f32 - rb; 37];
                let vd = vec![0.5 * rank as f32 + 3.0; 64];
                let px = w.issue_all_reduce(rank, Axis::X, &vx, Precision::Fp32);
                let py = w.issue_all_reduce(rank, Axis::Y, &vy, Precision::Fp32);
                let pg = w.issue_all_gather(rank, Axis::Y, &[rank as f32]);
                let pd = w.issue_all_reduce(rank, Axis::Dp, &vd, Precision::Fp32);
                // a second X op while the first is still in flight
                let vx2 = vec![1.0; 10];
                let px2 = w.issue_all_reduce(rank, Axis::X, &vx2, Precision::Fp32);
                w.progress(rank);

                let mut ox2 = vec![0.0; 10];
                px2.wait_into(&mut ox2); // out of issue order on X
                let mut ox = vec![0.0; 100];
                px.wait_into(&mut ox);
                let mut od = vec![0.0; 64];
                pd.wait_into(&mut od);
                let gathered = pg.wait();
                let mut oy = vec![0.0; 37];
                py.wait_into(&mut oy);

                let want_x = sum_of(Axis::X, &|r| r as f32 + rb);
                let want_y = sum_of(Axis::Y, &|r| 2.0 * r as f32 - rb);
                let want_d = sum_of(Axis::Dp, &|r| 0.5 * r as f32 + 3.0);
                assert!(ox.iter().all(|&v| v == want_x), "round {round}: X sum");
                assert!(oy.iter().all(|&v| v == want_y), "round {round}: Y sum");
                assert!(od.iter().all(|&v| v == want_d), "round {round}: Dp sum");
                assert!(ox2.iter().all(|&v| v == g.axis_size(Axis::X) as f32));
                let want_members: Vec<f32> =
                    g.group_ranks(rank, Axis::Y).iter().map(|&r| r as f32).collect();
                let got: Vec<f32> = gathered.into_iter().flatten().collect();
                assert_eq!(got, want_members, "round {round}: Y gather order");
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
}

#[test]
fn bf16_byte_accounting_is_exact_under_chunking() {
    // payload of 10 elems with 3-elem chunks: the per-chunk accounting must
    // still total elems * 2 bytes per contributing rank
    let grid = Grid4D::new(1, 2, 1, 1);
    let world = Arc::new(CommWorld::with_chunk_elems(grid, 3));
    let mut hs = vec![];
    for rank in 0..2 {
        let w = world.clone();
        hs.push(std::thread::spawn(move || {
            let mut v: Vec<f32> = (0..10).map(|i| (rank * 10 + i) as f32).collect();
            w.all_reduce(rank, Axis::X, &mut v, Precision::Bf16);
            v
        }));
    }
    for h in hs {
        let v = h.join().unwrap();
        // bf16 rounding is exact for these small integers
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, (10 + 2 * i) as f32);
        }
    }
    let (ops, bytes) = world.stats(Axis::X);
    assert_eq!(ops, 2, "one op per contributing rank");
    assert_eq!(bytes, 2 * 10 * 2, "bf16 halves the accounted payload");
}

#[test]
fn mismatched_lengths_error_instead_of_deadlocking() {
    // rank 0 reduces 4 elems, rank 1 reduces 8: the length handshake must
    // poison the group so BOTH ranks fail fast with a message instead of
    // hanging in the rendezvous
    let grid = Grid4D::new(1, 2, 1, 1);
    let world = Arc::new(CommWorld::new(grid));
    let mut hs = vec![];
    for rank in 0..2usize {
        let w = world.clone();
        hs.push(std::thread::spawn(move || {
            let mut v = vec![1.0f32; if rank == 0 { 4 } else { 8 }];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
        }));
    }
    for h in hs {
        assert!(h.join().is_err(), "mismatched collective must panic, not hang");
    }
}

#[test]
fn mismatch_poison_cascades_to_bystander_groups() {
    // ranks 0 and 1 mismatch on their X group; ranks 2 and 3 wait on Y
    // collectives whose peers (0 resp. 1) die — the poison must cascade
    // through the dead ranks' other groups so the bystanders fail fast
    // instead of waiting forever
    let grid = Grid4D::new(1, 2, 2, 1);
    let world = Arc::new(CommWorld::new(grid));
    let mut hs = vec![];
    for rank in 0..4usize {
        let w = world.clone();
        hs.push(std::thread::spawn(move || match rank {
            0 => {
                let mut v = vec![1.0f32; 4];
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            }
            1 => {
                let mut v = vec![1.0f32; 8]; // length mismatch vs rank 0
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            }
            _ => {
                // Y groups are {0,2} and {1,3}: peers never arrive
                let mut v = vec![1.0f32; 3];
                w.all_reduce(rank, Axis::Y, &mut v, Precision::Fp32);
            }
        }));
    }
    for (rank, h) in hs.into_iter().enumerate() {
        assert!(h.join().is_err(), "rank {rank} must fail fast, not hang");
    }
}

#[test]
fn kind_mismatch_also_errors_cleanly() {
    // same seq, one rank reduces while the other gathers
    let grid = Grid4D::new(1, 2, 1, 1);
    let world = Arc::new(CommWorld::new(grid));
    let mut hs = vec![];
    for rank in 0..2usize {
        let w = world.clone();
        hs.push(std::thread::spawn(move || {
            if rank == 0 {
                let mut v = vec![1.0f32; 4];
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            } else {
                let _ = w.all_gather(rank, Axis::X, &[1.0, 2.0]);
            }
        }));
    }
    for h in hs {
        assert!(h.join().is_err(), "kind mismatch must panic, not hang");
    }
}
