//! Integration tests of §V-D communication overlap at the *engine*
//! level: bitwise equality of overlap-on vs overlap-off training
//! trajectories, and bitwise determinism across repeated runs.
//!
//! The collective-engine contracts that used to live here (concurrent
//! in-flight ops, byte accounting under chunking, the mismatch
//! handshake and its poison cascade) moved into the backend-
//! parameterized battery in `tests/transport_conformance.rs`, which
//! runs them against the in-process, Unix-socket and TCP transports
//! alike.

use std::sync::Arc;

use scalegnn::comm::{CommWorld, Precision};
use scalegnn::graph::datasets;
use scalegnn::grid::Grid4D;
use scalegnn::model::GcnDims;
use scalegnn::pmm::{PmmCtx, PmmGcn};
use scalegnn::tensor::Mat;

fn tiny_dims() -> GcnDims {
    GcnDims { d_in: 16, d_h: 16, d_out: 4, layers: 2, dropout: 0.3, weight_decay: 0.0 }
}

/// Run `steps` engine steps on every rank of `grid` with the given §V-D
/// overlap setting; returns per-rank (losses, gathered params).
fn run_engine_overlap(
    grid: Grid4D,
    overlap: bool,
    steps: u64,
    seed: u64,
) -> Vec<(Vec<f32>, Vec<Mat>)> {
    let data = Arc::new(datasets::load("tiny").unwrap());
    let dims = tiny_dims();
    let world = Arc::new(CommWorld::new(grid));
    let mut hs = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        hs.push(std::thread::spawn(move || {
            let ctx = PmmCtx::new(grid, r, &w, Precision::Fp32);
            let mut eng = PmmGcn::new(ctx, dims, 48, d, seed);
            eng.set_overlap(overlap);
            let mut losses = vec![];
            for s in 0..steps {
                losses.push(eng.train_step(s, 5e-3).loss);
            }
            (losses, eng.gather_params())
        }));
    }
    hs.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn overlap_on_and_off_are_bitwise_identical() {
    // DP=2 so the per-layer DP gradient buckets are exercised too
    for grid in [Grid4D::new(2, 2, 1, 1), Grid4D::new(1, 2, 2, 2)] {
        let on = run_engine_overlap(grid, true, 3, 42);
        let off = run_engine_overlap(grid, false, 3, 42);
        for (rank, (a, b)) in on.iter().zip(&off).enumerate() {
            assert_eq!(a.0, b.0, "grid {grid:?} rank {rank}: losses diverged");
            assert_eq!(a.1.len(), b.1.len());
            for (i, (pa, pb)) in a.1.iter().zip(&b.1).enumerate() {
                assert_eq!(pa.data, pb.data, "grid {grid:?} rank {rank} param {i}");
            }
        }
    }
}

#[test]
fn repeated_overlap_runs_are_bitwise_deterministic() {
    // arrival order must never leak into the sums (group-index-ordered
    // reduction), so two identical runs agree to the bit
    let grid = Grid4D::new(1, 2, 2, 1);
    let a = run_engine_overlap(grid, true, 3, 7);
    let b = run_engine_overlap(grid, true, 3, 7);
    for ((la, pa), (lb, pb)) in a.iter().zip(&b) {
        assert_eq!(la, lb);
        for (ma, mb) in pa.iter().zip(pb) {
            assert_eq!(ma.data, mb.data);
        }
    }
}
