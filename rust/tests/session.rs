//! Session-API tests: `RunSpec` JSON round-trips losslessly, every
//! `SpecError` variant triggers, observers see the full stream, and —
//! the load-bearing guarantee — `session::run` is **bitwise identical**
//! to the legacy entry points (reference trainer, OOC trainer, PMM
//! engine) for mirroring specs, with §V-D overlap both on and off.

use std::path::PathBuf;
use std::sync::Arc;

use scalegnn::comm::{ChaosMode, ChaosSpec, CommWorld, Precision, TransportTuning};
use scalegnn::graph::datasets;
use scalegnn::grid::Grid4D;
use scalegnn::model::GcnDims;
use scalegnn::pmm::{PmmCtx, PmmGcn};
use scalegnn::sampling::SamplerKind;
use scalegnn::session::{
    self, BackendKind, FaultSpec, JsonlObserver, RunReport, RunSpec, SpecError, StepObserver,
    StepReport,
};
use scalegnn::trainer::{self, OocTrainConfig, TrainConfig};
use scalegnn::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("scalegnn_session_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ---------------------------------------------------------------------------
// RunSpec JSON round-trip
// ---------------------------------------------------------------------------

#[test]
fn runspec_json_roundtrip_is_lossless() {
    let specs = vec![
        RunSpec::new(BackendKind::Pmm, "tiny")
            .grid(2, 2, 2, 1)
            .model(16, 2, 0.5)
            .batch(64)
            .steps(13)
            .lr(5e-3)
            // above 2^53: must survive the JSON round-trip bit-exactly
            .seed(0xDEAD_BEEF_DEAD_BEEF)
            .precision(Precision::Bf16)
            .overlap(false)
            .final_eval(true),
        RunSpec::new(BackendKind::Ooc, "tiny")
            .store(PathBuf::from("/tmp/x.pallas"))
            .cache_mb(16)
            .steps(50)
            .prefetch(false),
        RunSpec::new(BackendKind::Reference, "products_sim")
            .sampler(SamplerKind::GraphSage)
            .epochs(3)
            .eval_every(2)
            .target_acc(0.7)
            .artifacts(PathBuf::from("somewhere/artifacts")),
        RunSpec::new(BackendKind::Sim, "papers100m_sim")
            .grid(1, 4, 4, 4)
            .sim("frontier", Some(0.25), vec![1, 2, 4, 8]),
        RunSpec::new(BackendKind::Pmm, "tiny")
            .grid(1, 2, 1, 1)
            .model(16, 2, 0.0)
            .steps(8)
            .checkpoint(PathBuf::from("/tmp/ckpts"), 2, 3)
            .resume(true)
            .fault(FaultSpec::KillRank { rank: 1, step: 5 }),
        RunSpec::new(BackendKind::Ooc, "tiny")
            .store(PathBuf::from("/tmp/x.pallas"))
            .steps(10)
            .checkpoint(PathBuf::from("ckpts"), 5, 1)
            .fault(FaultSpec::TruncateNewest),
        // the fault-tolerance surface: stall fault, every tuning knob,
        // and a chaos schedule (seed above 2^53, like the run seed)
        RunSpec::new(BackendKind::Pmm, "tiny")
            .grid(1, 2, 1, 1)
            .model(16, 2, 0.0)
            .steps(8)
            .checkpoint(PathBuf::from("/tmp/ckpts"), 2, 3)
            .fault(FaultSpec::StallRank { rank: 1, step: 5, ms: 750 })
            .tuning(TransportTuning {
                connect_timeout_ms: Some(2_000),
                heartbeat_ms: Some(250),
                wait_timeout_ms: Some(500),
                rejoin_grace_ms: Some(3_000),
            })
            .chaos(ChaosSpec::with_modes(
                0xFEED_FACE_FEED_FACE,
                0.25,
                vec![ChaosMode::Delay, ChaosMode::Drop, ChaosMode::Corrupt],
            )),
    ];
    for spec in specs {
        let text = spec.to_json_string();
        let back = RunSpec::from_json_str(&text)
            .unwrap_or_else(|e| panic!("reparse failed for {text}: {e}"));
        assert_eq!(back, spec, "round-trip changed the spec: {text}");
        // and serialization is stable
        assert_eq!(back.to_json_string(), text);
    }
}

#[test]
fn checked_in_example_specs_parse_and_validate() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/specs");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("examples/specs exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let spec = RunSpec::from_json_str(&text)
            .unwrap_or_else(|e| panic!("{} does not parse: {e}", path.display()));
        if let Err(errs) = spec.validate() {
            panic!("{} does not validate: {errs:?}", path.display());
        }
        seen += 1;
    }
    assert!(seen >= 4, "expected the checked-in spec files, found {seen}");
}

#[test]
fn from_json_rejects_unknown_fields_and_bad_values() {
    let base = RunSpec::new(BackendKind::Pmm, "tiny").steps(1);
    let with_typo = base.to_json_string().replacen("\"steps\"", "\"stepz\"", 1);
    let err = RunSpec::from_json_str(&with_typo).unwrap_err();
    assert!(err.contains("stepz"), "error should name the field: {err}");

    let err = RunSpec::from_json_str(r#"{"backend": "warp", "dataset": "tiny"}"#).unwrap_err();
    assert!(err.contains("warp") && err.contains("accepted"), "{err}");

    let err =
        RunSpec::from_json_str(r#"{"backend": "pmm", "dataset": "tiny", "grid": "2by2"}"#)
            .unwrap_err();
    assert!(err.contains("2by2"), "{err}");

    // typos inside nested sections are rejected too
    let err = RunSpec::from_json_str(
        r#"{"backend": "sim", "dataset": "papers100m_sim",
            "sim": {"machine": "perlmutter", "gd_sweep": [8], "hide_fraction": 0.9}}"#,
    )
    .unwrap_err();
    assert!(err.contains("sim.hide_fraction"), "{err}");

    // an unknown chaos mode is named, with the accepted set
    let err = RunSpec::from_json_str(
        r#"{"backend": "pmm", "dataset": "tiny", "steps": 2,
            "chaos": {"seed": 7, "rate": 0.1, "modes": ["delay", "gremlin"]}}"#,
    )
    .unwrap_err();
    assert!(err.contains("gremlin") && err.contains("accepted"), "{err}");

    // non-numeric deadline values name the offending transport field
    let err = RunSpec::from_json_str(
        r#"{"backend": "pmm", "dataset": "tiny", "steps": 2,
            "transport": {"endpoint": "inproc", "wait_timeout_ms": "soon"}}"#,
    )
    .unwrap_err();
    assert!(err.contains("wait_timeout_ms"), "{err}");
}

// ---------------------------------------------------------------------------
// SpecError coverage: every variant triggers
// ---------------------------------------------------------------------------

fn errs_of(spec: &RunSpec) -> Vec<SpecError> {
    spec.validate().expect_err("spec should be invalid")
}

#[test]
fn every_spec_error_variant_triggers() {
    // UnknownDataset
    let s = RunSpec::new(BackendKind::Pmm, "no_such_dataset").steps(1);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::UnknownDataset(_))));

    // ZeroGridAxis
    let s = RunSpec::new(BackendKind::Pmm, "tiny").grid(0, 1, 1, 1).steps(1);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::ZeroGridAxis(_))));

    // WorldTooLarge
    let s = RunSpec::new(BackendKind::Pmm, "tiny").grid(300, 1, 1, 1).steps(1);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::WorldTooLarge { .. })));

    // SourceMismatch: ooc backend without a store...
    let s = RunSpec::new(BackendKind::Ooc, "tiny").steps(1);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::SourceMismatch { .. })));
    // ...and the OOC + PMM combination
    let s = RunSpec::new(BackendKind::Pmm, "tiny").store(PathBuf::from("g.pallas")).steps(1);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::SourceMismatch { .. })));

    // SamplerUnsupported
    let s = RunSpec::new(BackendKind::Pmm, "tiny").sampler(SamplerKind::GraphSage).steps(1);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::SamplerUnsupported(_))));

    // GridUnsupported (reference parallelizes over Gd only)
    let s = RunSpec::new(BackendKind::Reference, "tiny").grid(1, 2, 1, 1).steps(1);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::GridUnsupported(_))));

    // HideFracRange
    let s = RunSpec::new(BackendKind::Sim, "tiny").sim("perlmutter", Some(1.5), vec![1]);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::HideFracRange(_))));

    // UnknownMachine
    let s = RunSpec::new(BackendKind::Sim, "tiny").sim("laptop", None, vec![1]);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::UnknownMachine(_))));

    // SimSectionMismatch, both directions
    let s = RunSpec::new(BackendKind::Sim, "tiny");
    assert!(errs_of(&s)
        .iter()
        .any(|e| matches!(e, SpecError::SimSectionMismatch { present: false, .. })));
    let s = RunSpec::new(BackendKind::Pmm, "tiny").steps(1).sim("perlmutter", None, vec![1]);
    assert!(errs_of(&s)
        .iter()
        .any(|e| matches!(e, SpecError::SimSectionMismatch { present: true, .. })));

    // EmptySweep
    let s = RunSpec::new(BackendKind::Sim, "tiny").sim("perlmutter", None, vec![]);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::EmptySweep)));

    // NoWork
    let s = RunSpec::new(BackendKind::Pmm, "tiny");
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::NoWork(_))));
    let mut s = RunSpec::new(BackendKind::Reference, "tiny");
    s.epochs = 0;
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::NoWork(_))));

    // BatchTooLarge (tiny has 512 vertices) — zero is rejected too
    let s = RunSpec::new(BackendKind::Pmm, "tiny").batch(10_000).steps(1);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BatchTooLarge { .. })));
    let s = RunSpec::new(BackendKind::Pmm, "tiny").batch(0).steps(1);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BatchTooLarge { .. })));
    // ...and the OOC backend's implicit 1024 default is checked as well
    let s = RunSpec::new(BackendKind::Ooc, "tiny").store(PathBuf::from("g.pallas")).steps(1);
    assert!(errs_of(&s)
        .iter()
        .any(|e| matches!(e, SpecError::BatchTooLarge { batch: 1024, .. })));

    // BatchUnsupported (the reference batch is fixed by the artifact)
    let s = RunSpec::new(BackendKind::Reference, "tiny").steps(5).batch(64);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BatchUnsupported(_))));

    // FieldUnsupported: fields a backend would silently ignore
    let s = RunSpec::new(BackendKind::Pmm, "tiny").steps(1).target_acc(0.9);
    assert!(errs_of(&s)
        .iter()
        .any(|e| matches!(e, SpecError::FieldUnsupported { field: "target_acc", .. })));
    let s = RunSpec::new(BackendKind::Pmm, "tiny").steps(1).prefetch(false);
    assert!(errs_of(&s)
        .iter()
        .any(|e| matches!(e, SpecError::FieldUnsupported { field: "prefetch", .. })));
    let s = RunSpec::new(BackendKind::Reference, "tiny").steps(1).final_eval(true);
    assert!(errs_of(&s)
        .iter()
        .any(|e| matches!(e, SpecError::FieldUnsupported { field: "final_eval", .. })));
    // reference dims AND dropout come from the artifact manifest
    let s = RunSpec::new(BackendKind::Reference, "tiny").steps(1).model(512, 4, 0.0);
    assert!(errs_of(&s)
        .iter()
        .any(|e| matches!(e, SpecError::FieldUnsupported { field: "model", .. })));
    let s = RunSpec::new(BackendKind::Reference, "tiny").steps(1).model(16, 2, 0.9);
    assert!(errs_of(&s)
        .iter()
        .any(|e| matches!(e, SpecError::FieldUnsupported { field: "model", .. })));
    let mut s = RunSpec::new(BackendKind::Reference, "tiny").steps(1);
    s.eval_every_epochs = 0;
    assert!(errs_of(&s)
        .iter()
        .any(|e| matches!(e, SpecError::FieldUnsupported { .. })));

    // BadModel
    let s = RunSpec::new(BackendKind::Pmm, "tiny").model(0, 2, 0.0).steps(1);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadModel(_))));

    // BadLr
    let s = RunSpec::new(BackendKind::Pmm, "tiny").steps(1).lr(-1.0);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadLr(_))));

    // BadCheckpoint: zero cadence, zero retention, resume without a dir
    let s = RunSpec::new(BackendKind::Pmm, "tiny")
        .steps(4)
        .checkpoint(PathBuf::from("c"), 0, 2);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadCheckpoint(_))));
    let s = RunSpec::new(BackendKind::Pmm, "tiny")
        .steps(4)
        .checkpoint(PathBuf::from("c"), 2, 0);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadCheckpoint(_))));
    let s = RunSpec::new(BackendKind::Pmm, "tiny").steps(4).resume(true);
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadCheckpoint(_))));

    // BadFault: no checkpoint to recover from, wrong backend, rank/step
    // out of range
    let s = RunSpec::new(BackendKind::Pmm, "tiny")
        .steps(4)
        .fault(FaultSpec::KillRank { rank: 0, step: 1 });
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadFault(_))));
    let s = RunSpec::new(BackendKind::Ooc, "tiny")
        .store(PathBuf::from("g.pallas"))
        .batch(128)
        .steps(4)
        .checkpoint(PathBuf::from("c"), 2, 2)
        .fault(FaultSpec::KillRank { rank: 0, step: 1 });
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadFault(_))));
    let s = RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(1, 2, 1, 1)
        .steps(4)
        .checkpoint(PathBuf::from("c"), 2, 2)
        .fault(FaultSpec::KillRank { rank: 5, step: 1 });
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadFault(_))));
    let s = RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(1, 2, 1, 1)
        .steps(4)
        .checkpoint(PathBuf::from("c"), 2, 2)
        .fault(FaultSpec::KillRank { rank: 0, step: 9 });
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadFault(_))));

    // FieldUnsupported: the sim backend has no training state to snapshot
    let s = RunSpec::new(BackendKind::Sim, "tiny")
        .sim("perlmutter", None, vec![1])
        .checkpoint(PathBuf::from("c"), 2, 2);
    assert!(errs_of(&s)
        .iter()
        .any(|e| matches!(e, SpecError::FieldUnsupported { field: "checkpoint", .. })));

    // BadFault: a stall of zero milliseconds injects nothing
    let s = RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(1, 2, 1, 1)
        .steps(4)
        .checkpoint(PathBuf::from("c"), 2, 2)
        .fault(FaultSpec::StallRank { rank: 0, step: 1, ms: 0 });
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadFault(_))));

    // BadTransport: a zero deadline would silently disable the no-hang
    // guarantee, and anything above a day is a unit mistake
    let s = RunSpec::new(BackendKind::Pmm, "tiny")
        .steps(1)
        .tuning(TransportTuning { wait_timeout_ms: Some(0), ..Default::default() });
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadTransport(_))));
    let s = RunSpec::new(BackendKind::Pmm, "tiny")
        .steps(1)
        .tuning(TransportTuning { rejoin_grace_ms: Some(86_400_001), ..Default::default() });
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadTransport(_))));

    // BadChaos: wrong backend, and a rate outside (0, 1]
    let s = RunSpec::new(BackendKind::Ooc, "tiny")
        .store(PathBuf::from("g.pallas"))
        .batch(128)
        .steps(4)
        .chaos(ChaosSpec::new(7, 0.1));
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadChaos(_))));
    let s = RunSpec::new(BackendKind::Pmm, "tiny").steps(1).chaos(ChaosSpec::new(7, 1.5));
    assert!(errs_of(&s).iter().any(|e| matches!(e, SpecError::BadChaos(_))));
}

#[test]
fn validate_collects_every_violation() {
    let s = RunSpec::new(BackendKind::Pmm, "no_such_dataset")
        .sampler(SamplerKind::GraphSage)
        .model(0, 0, 0.0)
        .lr(f32::NAN);
    let errs = errs_of(&s);
    assert!(errs.len() >= 4, "expected all violations at once, got {errs:?}");
    // and run() refuses with a message naming them
    let err = session::run_silent(&s).unwrap_err().to_string();
    assert!(err.contains("invalid spec"), "{err}");
}

// ---------------------------------------------------------------------------
// Bitwise identity: session vs legacy entry points
// ---------------------------------------------------------------------------

fn assert_bitwise_eq(a: &[(u64, f32)], b: &[(u64, f32)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: curve lengths differ");
    for (&(sa, la), &(sb, lb)) in a.iter().zip(b.iter()) {
        assert_eq!(sa, sb, "{what}: step index diverged");
        assert_eq!(la.to_bits(), lb.to_bits(), "{what}: loss at step {sa}: {la} vs {lb}");
    }
}

/// The legacy PMM entry point: rank threads stepping `PmmGcn` directly
/// (exactly what `cmd_pmm_train` used to hand-roll).
fn legacy_pmm_losses(grid: Grid4D, overlap: bool, steps: u64) -> Vec<(u64, f32)> {
    let data = Arc::new(datasets::load("tiny").unwrap());
    let ds = datasets::spec("tiny").unwrap();
    let batch = ds.batch;
    let dims = GcnDims {
        d_in: ds.planted.d_in,
        d_h: 16,
        d_out: ds.planted.classes,
        layers: 2,
        dropout: 0.5,
        weight_decay: 0.0,
    };
    let world = Arc::new(CommWorld::new(grid));
    let mut handles = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        handles.push(std::thread::spawn(move || {
            let ctx = PmmCtx::new(grid, r, &w, Precision::Fp32);
            let mut eng = PmmGcn::new(ctx, dims, batch, d, 42);
            eng.set_overlap(overlap);
            (0..steps).map(|s| (s, eng.train_step(s, 5e-3).loss)).collect::<Vec<_>>()
        }));
    }
    let mut out = None;
    for h in handles {
        let losses = h.join().unwrap();
        out.get_or_insert(losses);
    }
    out.unwrap()
}

#[test]
fn pmm_session_is_bitwise_identical_to_legacy() {
    for grid in [Grid4D::new(1, 2, 2, 2), Grid4D::new(2, 2, 1, 1)] {
        for overlap in [true, false] {
            let steps = 6u64;
            let legacy = legacy_pmm_losses(grid, overlap, steps);
            let spec = RunSpec::new(BackendKind::Pmm, "tiny")
                .grid(grid.gd, grid.gx, grid.gy, grid.gz)
                .model(16, 2, 0.5)
                .steps(steps)
                .lr(5e-3)
                .seed(42)
                .overlap(overlap);
            let report = session::run_silent(&spec).unwrap();
            assert_eq!(report.steps, steps);
            assert_bitwise_eq(
                &legacy,
                &report.loss_curve,
                &format!("pmm grid {:?} overlap {overlap}", (grid.gd, grid.gx, grid.gy, grid.gz)),
            );
            // repeated session runs are deterministic too
            let again = session::run_silent(&spec).unwrap();
            assert_bitwise_eq(&report.loss_curve, &again.loss_curve, "pmm repeat");
        }
    }
}

#[test]
fn ooc_session_is_bitwise_identical_to_legacy() {
    let dir = tmp_dir("ooc");
    let store = dir.join("tiny.pallas");
    let mut cfg = OocTrainConfig::quick(store.clone());
    cfg.dataset = Some("tiny".into());
    cfg.cache_bytes = 4 << 20;
    cfg.batch = 128;
    cfg.d_h = 16;
    cfg.layers = 2;
    cfg.steps = 20;
    cfg.lr = 1e-2;
    cfg.seed = 42;
    let legacy = trainer::train_from_store(&cfg).unwrap();

    for overlap in [true, false] {
        let spec = RunSpec::new(BackendKind::Ooc, "tiny")
            .store(store.clone())
            .cache_mb(4)
            .batch(128)
            .model(16, 2, 0.0)
            .steps(20)
            .lr(1e-2)
            .seed(42)
            .overlap(overlap);
        let report = session::run_silent(&spec).unwrap();
        assert_bitwise_eq(
            &legacy.loss_curve,
            &report.loss_curve,
            &format!("ooc overlap {overlap}"),
        );
        let o = report.ooc.expect("ooc backend returns an ooc report");
        assert_eq!(o.final_loss.to_bits(), legacy.final_loss.to_bits());
        assert_eq!(o.store_bytes, legacy.store_bytes);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn reference_session_is_bitwise_identical_to_legacy() {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !scalegnn::runtime::pjrt_artifacts_available(&artifacts) {
        eprintln!("skipping: PJRT artifacts/backend not available");
        return;
    }
    for (dp, overlap) in [(1usize, true), (2, true), (2, false)] {
        let mut cfg = TrainConfig::quick("tiny", SamplerKind::ScaleGnnUniform);
        cfg.artifacts = artifacts.clone();
        cfg.dp = dp;
        cfg.max_steps = 12;
        cfg.lr = 5e-3;
        cfg.overlap = overlap;
        let legacy = trainer::train(&cfg).unwrap();

        let spec = RunSpec::new(BackendKind::Reference, "tiny")
            .grid(dp, 1, 1, 1)
            .steps(12)
            .lr(5e-3)
            .seed(42)
            .overlap(overlap)
            .artifacts(artifacts.clone());
        let report = session::run_silent(&spec).unwrap();
        assert_bitwise_eq(
            &legacy.loss_curve,
            &report.loss_curve,
            &format!("reference dp {dp} overlap {overlap}"),
        );
        let t = report.trainer.expect("reference backend returns a trainer report");
        assert_eq!(t.final_loss.to_bits(), legacy.final_loss.to_bits());
        assert_eq!(t.acc_curve, legacy.acc_curve);
    }
}

// ---------------------------------------------------------------------------
// Observer stream
// ---------------------------------------------------------------------------

#[derive(Default)]
struct CountState {
    started: usize,
    steps: Vec<u64>,
    finished: Option<u64>,
    last_done: bool,
}

/// Observer writing into shared state the test can inspect afterwards.
struct SharedObserver(std::rc::Rc<std::cell::RefCell<CountState>>);

impl StepObserver for SharedObserver {
    fn on_start(&mut self, _spec: &RunSpec) {
        self.0.borrow_mut().started += 1;
    }
    fn on_step(&mut self, r: &StepReport) {
        let mut s = self.0.borrow_mut();
        s.steps.push(r.step);
        s.last_done = r.done;
    }
    fn on_finish(&mut self, r: &RunReport) {
        self.0.borrow_mut().finished = Some(r.steps);
    }
}

#[test]
fn observers_see_every_step_in_order() {
    let spec = RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(1, 2, 1, 1)
        .model(16, 2, 0.0)
        .steps(5)
        .lr(5e-3);
    let state = std::rc::Rc::new(std::cell::RefCell::new(CountState::default()));
    let mut obs: Vec<Box<dyn StepObserver>> = vec![Box::new(SharedObserver(state.clone()))];
    let report = session::run(&spec, &mut obs).unwrap();
    drop(obs);
    let s = state.borrow();
    assert_eq!(s.started, 1, "on_start fires once");
    assert_eq!(s.steps, (0..5).collect::<Vec<u64>>(), "every step streamed, in order");
    assert!(s.last_done, "final step is flagged done");
    assert_eq!(s.finished, Some(5), "on_finish sees the final report");
    assert_eq!(report.steps, 5);
}

#[test]
fn jsonl_observer_streams_machine_readable_events() {
    let dir = tmp_dir("jsonl");
    let path = dir.join("events.jsonl");
    let spec = RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(1, 2, 1, 1)
        .model(16, 2, 0.0)
        .steps(4)
        .lr(5e-3);
    let mut obs: Vec<Box<dyn StepObserver>> =
        vec![Box::new(JsonlObserver::create(&path).unwrap())];
    let report = session::run(&spec, &mut obs).unwrap();
    drop(obs); // flush
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 1 + 4 + 1, "start + one per step + finish");
    let first = Json::parse(lines[0]).unwrap();
    assert_eq!(first.get("event").and_then(Json::as_str), Some("start"));
    // the start line embeds the exact spec
    let embedded = RunSpec::from_json(first.get("spec").unwrap()).unwrap();
    assert_eq!(embedded, spec);
    for line in &lines[1..5] {
        let v = Json::parse(line).unwrap();
        assert_eq!(v.get("event").and_then(Json::as_str), Some("step"));
        assert!(v.get("report").and_then(|r| r.get("loss")).is_some());
    }
    let last = Json::parse(lines[5]).unwrap();
    assert_eq!(last.get("event").and_then(Json::as_str), Some("finish"));
    assert_eq!(
        last.get("report").and_then(|r| r.get("steps")).and_then(Json::as_usize),
        Some(report.steps as usize)
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn eval_only_pmm_session_reports_accuracy() {
    let spec = RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(1, 2, 2, 1)
        .model(16, 2, 0.0)
        .steps(0)
        .final_eval(true);
    let report = session::run_silent(&spec).unwrap();
    assert_eq!(report.steps, 0);
    let (val, test) = report.pmm.unwrap().eval.expect("final_eval requested");
    assert!(val.is_finite() && test.is_finite());
}
