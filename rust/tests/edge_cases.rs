//! Edge-case and failure-injection integration tests across modules.

use std::sync::Arc;

use scalegnn::comm::{CommWorld, Precision};
use scalegnn::graph::{datasets, generate, partition_2d, Csr};
use scalegnn::grid::{Axis, Grid4D};
use scalegnn::sampling::{
    induce_rescaled, DistributedSubgraphBuilder, SamplerKind, UniformVertexSampler,
};
use scalegnn::trainer::{train, TrainConfig};
use scalegnn::util::rng::Rng;

#[test]
fn sampler_full_batch_equals_whole_graph() {
    // B = N: the "mini-batch" is the full graph, p = 1, no rescaling
    let g = generate::rmat(5, 4, 1).gcn_normalize();
    let s = UniformVertexSampler::new(g.rows, g.rows, 7);
    let sample = s.sample(0);
    assert_eq!(sample, (0..g.rows as u32).collect::<Vec<_>>());
    assert!((s.inclusion_prob() - 1.0).abs() < 1e-6);
    let mb = induce_rescaled(&g, &sample, s.inclusion_prob());
    assert_eq!(mb.adj.nnz(), g.nnz());
    assert!(mb.adj.to_dense().allclose(&g.to_dense(), 1e-6, 0.0));
}

#[test]
fn sampler_single_vertex_batch() {
    let g = generate::rmat(5, 4, 2).gcn_normalize();
    let s = UniformVertexSampler::new(g.rows, 1, 9);
    for step in 0..5 {
        let sample = s.sample(step);
        assert_eq!(sample.len(), 1);
        let mb = induce_rescaled(&g, &sample, s.inclusion_prob());
        // only the self loop can survive
        assert!(mb.adj.nnz() <= 1);
    }
}

#[test]
fn distributed_builder_handles_empty_local_ranges() {
    // a 16x1 grid over a 512-vertex graph: some ranks own tiny row ranges
    // and may see empty local samples at small B
    let g = generate::rmat(9, 4, 3).gcn_normalize();
    let sampler = UniformVertexSampler::new(g.rows, 8, 11);
    let shards = partition_2d(&g, 16, 1);
    let mut total = 0usize;
    for sh in shards {
        let mut b = DistributedSubgraphBuilder::new(sampler.clone(), sh);
        let out = b.build(0);
        total += out.local_rows();
    }
    assert_eq!(total, 8, "row ranges partition the sample");
}

#[test]
fn empty_graph_normalizes_to_self_loops() {
    let g = Csr::empty(10, 10).gcn_normalize();
    assert_eq!(g.nnz(), 10);
    for r in 0..10 {
        assert!(g.has_edge(r, r as u32));
        assert!((g.row(r).1[0] - 1.0).abs() < 1e-6);
    }
}

#[test]
fn train_rejects_unknown_dataset_and_missing_artifacts() {
    let mut cfg = TrainConfig::quick("nope", SamplerKind::ScaleGnnUniform);
    assert!(train(&cfg).is_err());
    cfg = TrainConfig::quick("tiny", SamplerKind::ScaleGnnUniform);
    cfg.artifacts = "/nonexistent/path".into();
    let err = train(&cfg).unwrap_err();
    assert!(format!("{err:#}").contains("artifacts"), "{err:#}");
}

#[test]
fn collectives_survive_many_rounds_of_mixed_ops() {
    // stress the slot-reuse protocol: interleave all-reduce / all-gather /
    // barrier across axes for many rounds
    let grid = Grid4D::new(2, 2, 1, 1);
    let world = Arc::new(CommWorld::new(grid));
    let mut hs = vec![];
    for rank in 0..grid.world_size() {
        let w = world.clone();
        hs.push(std::thread::spawn(move || {
            let mut acc = 0.0f32;
            for round in 0..200 {
                let mut v = vec![(rank + round) as f32; 7];
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                acc += v[0];
                let g = w.all_gather(rank, Axis::Dp, &[rank as f32], Precision::Fp32);
                acc += g.iter().map(|p| p[0]).sum::<f32>();
                w.barrier(rank, Axis::X);
                let mut d = vec![1.0f32];
                w.all_reduce(rank, Axis::Dp, &mut d, Precision::Bf16);
                acc += d[0];
            }
            acc
        }));
    }
    let outs: Vec<f32> = hs.into_iter().map(|h| h.join().unwrap()).collect();
    // ranks in the same X line share the X-reduction part; their Dp
    // gathers differ by exactly (1+3)-(0+2)=2 per round over 200 rounds
    assert!(outs.iter().all(|v| v.is_finite()));
    assert_eq!(outs[1] - outs[0], 400.0);
    // across DP groups the X-line sums differ by 4 per round (ranks 2,3
    // carry +2 each), Dp parts are identical within a pair
    assert_eq!(outs[2] - outs[0], 800.0);
    assert_eq!(outs[3] - outs[1], 800.0);
}

#[test]
fn graphsage_handles_isolated_vertices() {
    // a graph with isolated vertices must not hang or panic the sampler
    let mut triples = vec![];
    for i in 0..50u32 {
        triples.push((i, (i + 1) % 50, 1.0));
    }
    // vertices 50..99 are isolated
    let raw = Csr::from_triples(100, 100, triples).symmetrize();
    let data = scalegnn::graph::Dataset {
        name: "iso".into(),
        n: 100,
        adj: raw.gcn_normalize(),
        raw_adj: raw,
        features: scalegnn::tensor::Mat::zeros(100, 4),
        labels: vec![0; 100],
        classes: 2,
        split: vec![0; 100],
    };
    let s = scalegnn::sampling::GraphSageSampler::new(16, 2, 3);
    for step in 0..5 {
        let b = s.sample(&data, step, false);
        assert_eq!(b.vertices.len(), 16);
    }
}

#[test]
fn pmm_on_grid_larger_than_typical_with_uneven_dims() {
    // 3x1x2 grid: dims not divisible by axis sizes exercise uneven bounds
    let grid = Grid4D::new(1, 3, 1, 2);
    let data = Arc::new(datasets::load("tiny").unwrap());
    let dims = scalegnn::model::GcnDims {
        d_in: 16,
        d_h: 16,
        d_out: 4,
        layers: 2,
        dropout: 0.0,
        weight_decay: 0.0,
    };
    let world = Arc::new(CommWorld::new(grid));
    let mut hs = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        hs.push(std::thread::spawn(move || {
            let ctx = scalegnn::pmm::PmmCtx::new(grid, r, &w, Precision::Fp32);
            let mut eng = scalegnn::pmm::PmmGcn::new(ctx, dims, 40, d, 3);
            let mut last = f32::NAN;
            for s in 0..3 {
                last = eng.train_step(s, 5e-3).loss;
            }
            last
        }));
    }
    let losses: Vec<f32> = hs.into_iter().map(|h| h.join().unwrap()).collect();
    for l in &losses {
        assert!(l.is_finite());
        assert!((l - losses[0]).abs() < 1e-5, "ranks disagree: {losses:?}");
    }
}

#[test]
fn rng_streams_do_not_collide_across_groups() {
    // property: different (seed, step) pairs give different samples with
    // overwhelming probability over many draws
    let mut seen = std::collections::HashSet::new();
    for seed in 0..20u64 {
        for step in 0..20u64 {
            let mut r = Rng::for_step(seed, step);
            seen.insert(r.next_u64());
        }
    }
    assert_eq!(seen.len(), 400);
}

#[test]
fn bench_edge_cap_overflow_truncates_gracefully() {
    use scalegnn::trainer::batch::BatchMaker;
    let data = Arc::new(datasets::load("tiny").unwrap());
    // absurdly small capacity forces truncation without panicking
    let mut m = BatchMaker::new(data, SamplerKind::ScaleGnnUniform, 32, 4, 2, 5);
    let b = m.make(0);
    assert_eq!(b.val.len(), 4);
    assert!(b.truncated > 0);
}
