//! Crash-recovery tests: interrupted-then-resumed runs are **bitwise
//! identical** to uninterrupted ones on every training backend, a rank
//! killed mid-run on the PMM backend recovers automatically from the last
//! checkpoint, a torn newest snapshot falls back to the previous valid
//! one — end to end through the session API — and a *real process* death
//! on the socket transport is reported by the coordinator and recovered
//! by relaunching the world with `--resume`.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

use scalegnn::comm::TransportTuning;
use scalegnn::session::{self, BackendKind, FaultSpec, RunSpec};
use scalegnn::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("scalegnn_crash_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn assert_bitwise_eq(a: &[(u64, f32)], b: &[(u64, f32)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: curve lengths differ");
    for (&(sa, la), &(sb, lb)) in a.iter().zip(b.iter()) {
        assert_eq!(sa, sb, "{what}: step index diverged");
        assert_eq!(la.to_bits(), lb.to_bits(), "{what}: loss at step {sa}: {la} vs {lb}");
    }
}

/// `prefix ++ resumed` must equal the uninterrupted curve bit for bit.
fn assert_resume_identity(
    full: &[(u64, f32)],
    prefix: &[(u64, f32)],
    resumed: &[(u64, f32)],
    what: &str,
) {
    let mut stitched = prefix.to_vec();
    stitched.extend_from_slice(resumed);
    assert_bitwise_eq(full, &stitched, what);
}

fn pmm_spec(steps: u64, overlap: bool) -> RunSpec {
    RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(1, 2, 1, 1)
        .model(16, 2, 0.5)
        .steps(steps)
        .lr(5e-3)
        .seed(42)
        .overlap(overlap)
}

// ---------------------------------------------------------------------------
// Bitwise resume identity, per backend
// ---------------------------------------------------------------------------

#[test]
fn pmm_resume_is_bitwise_identical_to_uninterrupted() {
    for overlap in [true, false] {
        let dir = tmp_dir(&format!("pmm_resume_{overlap}"));
        let full = session::run_silent(&pmm_spec(8, overlap)).unwrap();

        // interrupted run: 4 steps, snapshots after steps 1 and 3
        let first = session::run_silent(
            &pmm_spec(4, overlap).checkpoint(dir.clone(), 2, 4),
        )
        .unwrap();
        // resumed run: picks up at step 4 and finishes
        let second = session::run_silent(
            &pmm_spec(8, overlap).checkpoint(dir.clone(), 2, 4).resume(true),
        )
        .unwrap();
        assert_eq!(second.loss_curve.first().map(|&(s, _)| s), Some(4));
        assert_resume_identity(
            &full.loss_curve,
            &first.loss_curve,
            &second.loss_curve,
            &format!("pmm overlap {overlap}"),
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn ooc_resume_is_bitwise_identical_to_uninterrupted() {
    let dir = tmp_dir("ooc_resume");
    let store = dir.join("tiny.pallas");
    let spec = |steps: u64| {
        RunSpec::new(BackendKind::Ooc, "tiny")
            .store(store.clone())
            .cache_mb(4)
            .batch(128)
            .model(16, 2, 0.0)
            .steps(steps)
            .lr(1e-2)
            .seed(42)
    };
    let full = session::run_silent(&spec(12)).unwrap();
    let first = session::run_silent(&spec(6).checkpoint(dir.join("ckpt"), 3, 4)).unwrap();
    let second =
        session::run_silent(&spec(12).checkpoint(dir.join("ckpt"), 3, 4).resume(true)).unwrap();
    assert_eq!(second.loss_curve.first().map(|&(s, _)| s), Some(6));
    assert_resume_identity(&full.loss_curve, &first.loss_curve, &second.loss_curve, "ooc");
    assert_eq!(
        full.final_loss.to_bits(),
        second.final_loss.to_bits(),
        "resumed final loss must be bitwise identical"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn reference_resume_is_bitwise_identical_to_uninterrupted() {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !scalegnn::runtime::pjrt_artifacts_available(&artifacts) {
        eprintln!("skipping: PJRT artifacts/backend not available");
        return;
    }
    for dp in [1usize, 2] {
        let dir = tmp_dir(&format!("ref_resume_{dp}"));
        let spec = |steps: u64| {
            RunSpec::new(BackendKind::Reference, "tiny")
                .grid(dp, 1, 1, 1)
                .steps(steps)
                .lr(5e-3)
                .seed(42)
                .artifacts(artifacts.clone())
        };
        let full = session::run_silent(&spec(12)).unwrap();
        let first = session::run_silent(&spec(8).checkpoint(dir.clone(), 4, 4)).unwrap();
        let second =
            session::run_silent(&spec(12).checkpoint(dir.clone(), 4, 4).resume(true)).unwrap();
        // the interrupted run snapshotted after step 7; the resumed run
        // covers 8..12 and must reproduce the uninterrupted suffix exactly
        // (the reference curve records epoch boundaries, so compare the
        // entries both runs share rather than concatenating)
        assert!(first.steps == 8 && second.loss_curve.iter().all(|&(s, _)| s >= 8));
        let suffix: Vec<(u64, f32)> =
            full.loss_curve.iter().copied().filter(|&(s, _)| s >= 8).collect();
        assert_bitwise_eq(&suffix, &second.loss_curve, &format!("reference dp {dp}"));
        assert_eq!(full.final_loss.to_bits(), second.final_loss.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ---------------------------------------------------------------------------
// Elastic recovery: kill a rank mid-run, recover, match the unfaulted curve
// ---------------------------------------------------------------------------

#[test]
fn pmm_kill_rank_recovers_and_matches_unfaulted_curve() {
    for overlap in [true, false] {
        let dir = tmp_dir(&format!("pmm_kill_{overlap}"));
        let unfaulted = session::run_silent(&pmm_spec(8, overlap)).unwrap();
        assert!(unfaulted.failures.is_empty());
        assert_eq!(unfaulted.restarts, 0);

        let faulted = session::run_silent(
            &pmm_spec(8, overlap)
                .checkpoint(dir.clone(), 2, 4)
                .fault(FaultSpec::KillRank { rank: 1, step: 5 }),
        )
        .unwrap();
        assert_bitwise_eq(
            &unfaulted.loss_curve,
            &faulted.loss_curve,
            &format!("kill-rank recovery, overlap {overlap}"),
        );
        assert_eq!(faulted.restarts, 1, "exactly one world re-formation");
        assert_eq!(faulted.failures.len(), 1);
        let f = &faulted.failures[0];
        assert_eq!(f.rank, 1, "the origin rank is surfaced, not the cascade victim");
        assert_eq!(f.op, "injected-fault");
        assert_eq!(f.axis, "x");
        assert!(f.message.contains("kill rank 1 at step 5"), "{}", f.message);
        // snapshots exist for steps 2 and 4; the kill at step 5 means the
        // newest consistent state is step 4
        assert_eq!(f.resumed_from_step, Some(4));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The silent-rank case: a rank that is alive but contributes nothing
/// must be *diagnosed* by the wait deadline — every member's expired
/// wait names the same straggler with a `Stalled` origin — and the
/// recovered world must land on the unfaulted curve bit for bit.
#[test]
fn pmm_stall_rank_is_detected_as_stalled_and_recovery_matches_bitwise() {
    let dir = tmp_dir("pmm_stall");
    let tuning = TransportTuning { wait_timeout_ms: Some(500), ..Default::default() };
    let unfaulted = session::run_silent(&pmm_spec(8, true).tuning(tuning)).unwrap();
    assert!(unfaulted.failures.is_empty());

    // rank 1 goes silent for 2 s at step 5 — well past the 500 ms wait
    // deadline, so rank 0's expired wait must name it as the origin
    let faulted = session::run_silent(
        &pmm_spec(8, true)
            .tuning(tuning)
            .checkpoint(dir.clone(), 2, 4)
            .fault(FaultSpec::StallRank { rank: 1, step: 5, ms: 2_000 }),
    )
    .unwrap();
    assert_bitwise_eq(&unfaulted.loss_curve, &faulted.loss_curve, "stall-rank recovery");
    assert_eq!(faulted.restarts, 1, "exactly one world re-formation");
    assert_eq!(faulted.failures.len(), 1);
    let f = &faulted.failures[0];
    assert_eq!(f.rank, 1, "the silent rank is the diagnosed origin, not the waiter");
    assert!(
        f.message.contains("silent on") && f.message.contains("within 500 ms"),
        "a stall must be diagnosed by the deadline, not reported as a death: {}",
        f.message
    );
    // snapshots exist for steps 2 and 4; the stall at step 5 means the
    // newest world-consistent state is step 4
    assert_eq!(f.resumed_from_step, Some(4));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pmm_kill_without_checkpoint_section_is_rejected_up_front() {
    // a fault with nothing to recover from must fail validation, not hang
    let spec = pmm_spec(8, true).fault(FaultSpec::KillRank { rank: 1, step: 5 });
    let err = session::run_silent(&spec).unwrap_err().to_string();
    assert!(err.contains("invalid spec"), "{err}");
    assert!(err.contains("checkpoint"), "{err}");
}

// ---------------------------------------------------------------------------
// Torn-write fallback, end to end
// ---------------------------------------------------------------------------

#[test]
fn torn_newest_snapshot_falls_back_to_previous_valid_one() {
    for (fault, tag) in [
        (FaultSpec::TruncateNewest, "truncate"),
        (FaultSpec::CorruptNewest, "corrupt"),
    ] {
        let dir = tmp_dir(&format!("pmm_torn_{tag}"));
        let full = session::run_silent(&pmm_spec(6, true)).unwrap();
        // snapshots after steps 1, 3, 5 → files for steps 2, 4, 6
        let first =
            session::run_silent(&pmm_spec(6, true).checkpoint(dir.clone(), 2, 4)).unwrap();
        assert_bitwise_eq(&full.loss_curve, &first.loss_curve, "checkpointed run");

        // damage the newest snapshot on every rank, then resume: discovery
        // must skip it and replay from step 4 (undamaged), not error out
        let resumed = session::run_silent(
            &pmm_spec(6, true).checkpoint(dir.clone(), 2, 4).resume(true).fault(fault),
        )
        .unwrap();
        assert_eq!(
            resumed.loss_curve.first().map(|&(s, _)| s),
            Some(4),
            "{tag}: resume must fall back to the previous valid snapshot"
        );
        assert_bitwise_eq(&full.loss_curve[4..], &resumed.loss_curve, tag);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ---------------------------------------------------------------------------
// Cross-process elastic recovery over the socket transport, end to end
// through the real binaries: a killed rank takes its whole OS process
// down, the coordinator names the origin, and a relaunched world resumes
// from the shared checkpoint dir onto the unfaulted curve — bitwise.
// ---------------------------------------------------------------------------

fn spawn_coord(sock: &Path, extra: &[&str]) -> Child {
    Command::new(env!("CARGO_BIN_EXE_scalegnn-coord"))
        .args(["--grid", "1x2x1x1", "--unix"])
        .arg(sock)
        .arg("--quiet")
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn scalegnn-coord")
}

/// Launch one `pmm-train` rank process mirroring `pmm_spec(10, true)`,
/// attached to the Unix-socket coordinator at `sock`.
fn spawn_pmm_rank(rank: usize, sock: &Path, ckpt: &Path, extra: &[&str]) -> Child {
    let mut c = Command::new(env!("CARGO_BIN_EXE_scalegnn"));
    c.args(["pmm-train", "--dataset", "tiny", "--grid", "1x2x1x1", "--steps", "10"])
        .args(["--lr", "5e-3", "--seed", "42", "--d-h", "16", "--layers", "2"])
        .args(["--dropout", "0.5"])
        .arg("--transport")
        .arg(format!("unix:{}", sock.display()))
        .args(["--rank", &rank.to_string()])
        .arg("--checkpoint-dir")
        .arg(ckpt)
        .args(["--checkpoint-every", "2", "--checkpoint-keep", "4"])
        .args(extra)
        .stdout(Stdio::null())
        .stderr(Stdio::null());
    c.spawn().expect("spawn pmm-train rank")
}

/// Parse `report.loss_curve` out of a `--stats-json` file.
fn stats_loss_curve(path: &Path) -> Vec<(u64, f32)> {
    let text = std::fs::read_to_string(path).expect("stats json written");
    let doc = Json::parse(&text).expect("valid stats json");
    doc.get("report")
        .and_then(|r| r.get("loss_curve"))
        .and_then(Json::as_arr)
        .expect("report.loss_curve present")
        .iter()
        .map(|e| {
            let s = e.idx(0).and_then(Json::as_usize).expect("step index") as u64;
            let l = e.idx(1).and_then(Json::as_f64).expect("loss value") as f32;
            (s, l)
        })
        .collect()
}

#[test]
fn socket_kill_rank_reports_origin_and_resumed_relaunch_matches_bitwise() {
    let dir = tmp_dir("socket_kill");
    let ckpt = dir.join("ckpts");

    // the unfaulted reference curve, computed in-process
    let clean = session::run_silent(&pmm_spec(10, true)).unwrap();
    assert_eq!(clean.loss_curve.len(), 10);

    // generation 1: rank 1's *process* dies at step 5.  Snapshots exist
    // for steps 2 and 4; the step-5 fault fires before any step-5
    // collective, so step 4 is the newest world-consistent state.
    let sock1 = dir.join("gen1.sock");
    let coord = spawn_coord(&sock1, &[]);
    let kill = ["--kill-rank", "1", "--kill-step", "5"];
    let mut r0 = spawn_pmm_rank(0, &sock1, &ckpt, &kill);
    let mut r1 = spawn_pmm_rank(1, &sock1, &ckpt, &kill);
    assert!(!r1.wait().expect("rank 1").success(), "the killed rank must exit nonzero");
    assert!(!r0.wait().expect("rank 0").success(), "the surviving rank must fail too");
    let out = coord.wait_with_output().expect("coordinator");
    assert_eq!(out.status.code(), Some(1), "coordinator exits 1 on a failed world");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("failure origin rank 1 op injected-fault"),
        "coordinator must name the origin, got: {stdout}"
    );
    assert!(stdout.contains("kill rank 1 at step 5"), "coordinator stdout: {stdout}");

    // generation 2: fresh coordinator, same checkpoint dir, no fault,
    // --resume.  The relaunched world replays from step 4 and must land
    // on the unfaulted curve bit for bit.
    let sock2 = dir.join("gen2.sock");
    let stats = dir.join("stats-r0.json");
    let coord = spawn_coord(&sock2, &[]);
    let resume0 = ["--resume", "--stats-json", stats.to_str().unwrap()];
    let mut r0 = spawn_pmm_rank(0, &sock2, &ckpt, &resume0);
    let mut r1 = spawn_pmm_rank(1, &sock2, &ckpt, &["--resume"]);
    assert!(r0.wait().expect("rank 0").success(), "resumed rank 0 must succeed");
    assert!(r1.wait().expect("rank 1").success(), "resumed rank 1 must succeed");
    let out = coord.wait_with_output().expect("coordinator");
    assert!(
        out.status.success(),
        "recovered world must end clean, coordinator stdout: {}",
        String::from_utf8_lossy(&out.stdout)
    );

    let resumed = stats_loss_curve(&stats);
    assert_eq!(
        resumed.first().map(|&(s, _)| s),
        Some(4),
        "resume must replay from the newest world-consistent snapshot"
    );
    assert_bitwise_eq(&clean.loss_curve[4..], &resumed, "socket kill-rank recovery");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn socket_rejoin_reregisters_into_the_same_coordinator_and_matches_bitwise() {
    let dir = tmp_dir("socket_rejoin");
    let ckpt = dir.join("ckpts");
    let clean = session::run_silent(&pmm_spec(10, true)).unwrap();

    // ONE coordinator, ONE generation of processes: rank 1's worker dies
    // at step 5, but with a rejoin grace window the coordinator
    // broadcasts a rollback instead of tearing the world down and holds
    // both slots open.  Each rank's supervisor re-registers into the
    // next world generation and replays from the newest common snapshot
    // (step 4).  Nothing is relaunched, nothing exits nonzero — this is
    // the in-place rejoin path, in contrast to the relaunch flow above.
    let sock = dir.join("world.sock");
    let stats = dir.join("stats-r0.json");
    let coord = spawn_coord(&sock, &["--rejoin-grace-ms", "30000"]);
    let fault = ["--kill-rank", "1", "--kill-step", "5", "--rejoin-grace-ms", "30000"];
    let mut r0_extra: Vec<&str> = vec!["--stats-json", stats.to_str().unwrap()];
    r0_extra.extend_from_slice(&fault);
    let mut r0 = spawn_pmm_rank(0, &sock, &ckpt, &r0_extra);
    let mut r1 = spawn_pmm_rank(1, &sock, &ckpt, &fault);
    assert!(r0.wait().expect("rank 0").success(), "rank 0 must rejoin, not die");
    assert!(r1.wait().expect("rank 1").success(), "the faulted rank must rejoin, not die");
    let out = coord.wait_with_output().expect("coordinator");
    assert!(
        out.status.success(),
        "one rollback then a clean generation must exit 0, coordinator stdout: {}",
        String::from_utf8_lossy(&out.stdout)
    );

    // rank 0 kept its pre-fault prefix and replayed the tail from the
    // newest world-consistent snapshot: the full curve is the clean one
    let resumed = stats_loss_curve(&stats);
    assert_bitwise_eq(&clean.loss_curve, &resumed, "same-coordinator rejoin");
    let doc = Json::parse(&std::fs::read_to_string(&stats).unwrap()).unwrap();
    let rep = doc.get("report").expect("stats report");
    assert_eq!(rep.get("restarts").and_then(Json::as_usize), Some(1), "exactly one rejoin");
    let fails = rep.get("failures").and_then(Json::as_arr).expect("failures recorded");
    assert_eq!(fails.len(), 1);
    assert_eq!(fails[0].get("rank").and_then(Json::as_usize), Some(1));
    assert_eq!(fails[0].get("op").and_then(Json::as_str), Some("injected-fault"));
    assert_eq!(fails[0].get("resumed_from_step").and_then(Json::as_usize), Some(4));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_with_no_valid_snapshot_is_a_clean_error() {
    let dir = tmp_dir("pmm_no_snap");
    let err = session::run_silent(&pmm_spec(6, true).checkpoint(dir.clone(), 2, 4).resume(true))
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("no snapshot step is valid"),
        "expected a descriptive discovery error, got: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
