//! Transport conformance suite: one shared battery of collective-engine
//! contracts run against every [`Transport`] backend — the in-process
//! shared-memory engine, a Unix-domain-socket world, and a TCP-loopback
//! world (each socket world assembled by an in-process [`Coordinator`];
//! the multi-process tests at the bottom drive the real binaries).
//!
//! The battery pins, per backend:
//!   * bitwise-deterministic group-index-ordered reductions,
//!   * gather ordering, chunked multi-op overlap, out-of-order waits,
//!   * byte/op accounting (incl. bf16 half-width),
//!   * the failure contract: every mismatch / injected fault / peer
//!     death surfaces the SAME structured `CommError` origin on every
//!     member — an error, never a panic into the harness, never a hang,
//!   * poisoned-world stats queries answering with the origin.
//!
//! Below the battery: adversarial wire-format decode tests (truncated
//! frame, bad magic, wrong version, oversized length, CRC mismatch),
//! live mid-payload-disconnect / garbage-server tests, coordinator
//! registration rejection, and a multi-process bitwise-identity test
//! (the same `RunSpec` trained over sockets across real OS processes is
//! bitwise equal to the in-process threaded run).

use std::io::Write as _;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use scalegnn::checkpoint::crc32;
use scalegnn::comm::wire::{self, Msg, WireError, MAX_FRAME_PAYLOAD, WIRE_MAGIC};
use scalegnn::comm::{
    ChaosMode, ChaosSpec, CommError, CommWorld, CoordConfig, Coordinator, Endpoint, Precision,
    TransportTuning, DEFAULT_CHUNK_ELEMS,
};
use scalegnn::grid::{Axis, Grid4D};
use scalegnn::session::{run_silent, BackendKind, RunSpec};
use scalegnn::util::json::Json;

// ---------------------------------------------------------------------------
// Backend-parameterized harness
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq)]
enum BackendSel {
    InProc,
    Uds,
    Tcp,
}

fn uds_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sgnn-{}-{tag}.sock", std::process::id()))
}

/// Outcome of running one closure per rank over a backend: per-rank join
/// results, per-rank world handles (for stats / poison assertions), and
/// — for socket backends — the coordinator's join handle.
///
/// Make every world/stat assertion BEFORE calling [`WorldRun::finish`]:
/// finish drops the worlds (closing their connections so the coordinator
/// can exit) and returns the coordinator's verdict.
struct WorldRun {
    /// In-process worlds share one counter set; socket worlds count per
    /// rank.
    shared: bool,
    worlds: Vec<Arc<CommWorld>>,
    results: Vec<std::thread::Result<()>>,
    coord: Option<JoinHandle<anyhow::Result<Option<CommError>>>>,
}

impl WorldRun {
    /// World-total (ops, bytes) on an axis, backend-independent.
    fn total_stats(&self, axis: Axis) -> (u64, u64) {
        if self.shared {
            self.worlds[0].stats(axis)
        } else {
            self.worlds.iter().fold((0, 0), |(o, by), w| {
                let (a, b) = w.stats(axis);
                (o + a, by + b)
            })
        }
    }

    /// The failure origin visible to `rank` through its world handle.
    fn poison_of(&self, rank: usize) -> Option<CommError> {
        self.worlds[rank].poison_of(rank)
    }

    /// Drop the rank worlds (closing their connections) and return the
    /// coordinator's recorded failure (`None` for in-process backends or
    /// a clean socket world).
    fn finish(mut self) -> Option<CommError> {
        self.worlds.clear();
        match self.coord.take() {
            None => None,
            Some(h) => h.join().expect("coordinator thread").expect("coordinator run"),
        }
    }
}

/// Run `f(rank, world)` on every rank of `grid` over the selected
/// backend.  `chunk` sets the in-process reduction chunk size (socket
/// worlds reduce whole ops at the coordinator — same ordered sum, so
/// results are bitwise identical either way).
fn run_world<F>(b: BackendSel, tag: &str, grid: Grid4D, chunk: Option<usize>, f: F) -> WorldRun
where
    F: Fn(usize, &CommWorld) + Send + Sync + 'static,
{
    run_world_chaos(b, tag, grid, chunk, None, f)
}

/// As [`run_world`], optionally injecting a deterministic chaos
/// schedule into every rank's transport (the `chaos_*` battery
/// modules run the whole suite under it).
fn run_world_chaos<F>(
    b: BackendSel,
    tag: &str,
    grid: Grid4D,
    chunk: Option<usize>,
    chaos: Option<&ChaosSpec>,
    f: F,
) -> WorldRun
where
    F: Fn(usize, &CommWorld) + Send + Sync + 'static,
{
    let n = grid.world_size();
    let f = Arc::new(f);
    if b == BackendSel::InProc {
        let world = Arc::new(match (chunk, chaos) {
            (Some(c), None) => CommWorld::with_chunk_elems(grid, c),
            (None, None) => CommWorld::new(grid),
            (c, Some(spec)) => CommWorld::with_tuning(
                grid,
                c.unwrap_or(DEFAULT_CHUNK_ELEMS),
                &TransportTuning::default(),
                Some(spec),
            ),
        });
        let hs: Vec<_> = (0..n)
            .map(|r| {
                let (w, f) = (world.clone(), f.clone());
                std::thread::spawn(move || f(r, &w))
            })
            .collect();
        let results = hs.into_iter().map(|h| h.join()).collect();
        return WorldRun { shared: true, worlds: vec![world; n], results, coord: None };
    }
    let ep = match b {
        BackendSel::Uds => Endpoint::Unix(uds_path(tag)),
        _ => Endpoint::Tcp("127.0.0.1:0".to_string()),
    };
    let coord = Coordinator::bind(grid, &ep, CoordConfig::default()).expect("coordinator bind");
    let ep = coord.endpoint().clone();
    let coord = coord.spawn();
    let slots: Arc<Mutex<Vec<Option<Arc<CommWorld>>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    let chaos = chaos.cloned();
    let hs: Vec<_> = (0..n)
        .map(|r| {
            let (ep, f, slots) = (ep.clone(), f.clone(), slots.clone());
            let chaos = chaos.clone();
            std::thread::spawn(move || {
                let w = Arc::new(
                    CommWorld::connect_with(
                        grid,
                        r,
                        &ep,
                        &TransportTuning::default(),
                        chaos.as_ref(),
                    )
                    .expect("rank connect"),
                );
                slots.lock().unwrap()[r] = Some(w.clone());
                f(r, &w);
            })
        })
        .collect();
    let results = hs.into_iter().map(|h| h.join()).collect();
    let worlds =
        slots.lock().unwrap().iter().map(|w| w.clone().expect("rank connected")).collect();
    WorldRun { shared: false, worlds, results, coord: Some(coord) }
}

/// The schedule the chaos battery modules run under: low-rate,
/// delay-only.  `Delay` perturbs timing adversarially but never payload
/// bytes, so every battery assertion — including the bitwise ones —
/// must still hold; the destructive modes get their own deterministic
/// coverage in `tests/chaos.rs` and the CI soak job.
fn battery_chaos() -> ChaosSpec {
    ChaosSpec::with_modes(0x5EED_CAFE, 0.2, vec![ChaosMode::Delay])
}

/// Hard no-hang guard for the chaos battery: the run must finish inside
/// the budget or the test fails with a named timeout (never a CI hang).
fn with_no_hang_deadline<F: FnOnce() + Send + 'static>(name: &'static str, f: F) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(120)) {
        Ok(()) => h.join().expect("battery thread"),
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            panic!("{name}: chaos battery exceeded the 120 s no-hang deadline")
        }
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
            unreachable!("sender dropped without a panic");
        }
    }
}

/// Instantiate the battery for all three backends plus low-rate chaos
/// variants; each case becomes `inproc::<name>`, `uds::<name>`,
/// `tcp::<name>`, `chaos_inproc::<name>`, `chaos_uds::<name>`.
macro_rules! conformance {
    ($($name:ident),* $(,)?) => {
        mod inproc {
            $(#[test]
            fn $name() { super::$name(super::BackendSel::InProc, concat!("ip-", stringify!($name)), None); })*
        }
        mod uds {
            $(#[test]
            fn $name() { super::$name(super::BackendSel::Uds, concat!("u-", stringify!($name)), None); })*
        }
        mod tcp {
            $(#[test]
            fn $name() { super::$name(super::BackendSel::Tcp, concat!("t-", stringify!($name)), None); })*
        }
        mod chaos_inproc {
            $(#[test]
            fn $name() {
                super::with_no_hang_deadline(stringify!($name), || {
                    let chaos = super::battery_chaos();
                    super::$name(
                        super::BackendSel::InProc,
                        concat!("xi-", stringify!($name)),
                        Some(&chaos),
                    )
                });
            })*
        }
        mod chaos_uds {
            $(#[test]
            fn $name() {
                super::with_no_hang_deadline(stringify!($name), || {
                    let chaos = super::battery_chaos();
                    super::$name(
                        super::BackendSel::Uds,
                        concat!("xu-", stringify!($name)),
                        Some(&chaos),
                    )
                });
            })*
        }
    };
}

conformance!(
    reduces_across_axes_with_out_of_order_waits,
    gather_orders_by_group_index,
    bf16_accounting_is_exact,
    bf16_gather_rounds_identically,
    barriers_interleave_with_reduces,
    size1_world_short_circuits,
    length_mismatch_errors_all_ranks,
    kind_mismatch_errors_all_ranks,
    mismatch_poison_cascades_to_bystanders,
    injected_fault_reports_origin_everywhere,
    poisoned_stats_error_instead_of_blocking,
);

// ---------------------------------------------------------------------------
// The battery
// ---------------------------------------------------------------------------

/// Many in-flight ops per rank across all axes, tiny chunks (so every
/// in-process op is multi-chunk), waits out of issue order within an
/// axis.
fn reduces_across_axes_with_out_of_order_waits(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(2, 2, 2, 1);
    let run = run_world_chaos(b, tag, grid, Some(16), chaos, |rank, w| {
        let g = w.grid;
        let sum_of = |axis: Axis, f: &dyn Fn(usize) -> f32| -> f32 {
            g.group_ranks(rank, axis).into_iter().map(f).sum()
        };
        for round in 0..5u32 {
            let rb = round as f32;
            let vx = vec![rank as f32 + rb; 100];
            let vy = vec![2.0 * rank as f32 - rb; 37];
            let vd = vec![0.5 * rank as f32 + 3.0; 64];
            let px = w.issue_all_reduce(rank, Axis::X, &vx, Precision::Fp32);
            let py = w.issue_all_reduce(rank, Axis::Y, &vy, Precision::Fp32);
            let pg = w.issue_all_gather(rank, Axis::Y, &[rank as f32], Precision::Fp32);
            let pd = w.issue_all_reduce(rank, Axis::Dp, &vd, Precision::Fp32);
            let vx2 = vec![1.0; 10];
            let px2 = w.issue_all_reduce(rank, Axis::X, &vx2, Precision::Fp32);
            w.progress(rank);

            let mut ox2 = vec![0.0; 10];
            px2.wait_into(&mut ox2); // out of issue order on X
            let mut ox = vec![0.0; 100];
            px.wait_into(&mut ox);
            let mut od = vec![0.0; 64];
            pd.wait_into(&mut od);
            let gathered = pg.wait();
            let mut oy = vec![0.0; 37];
            py.wait_into(&mut oy);

            let want_x = sum_of(Axis::X, &|r| r as f32 + rb);
            let want_y = sum_of(Axis::Y, &|r| 2.0 * r as f32 - rb);
            let want_d = sum_of(Axis::Dp, &|r| 0.5 * r as f32 + 3.0);
            assert!(ox.iter().all(|&v| v == want_x), "round {round}: X sum");
            assert!(oy.iter().all(|&v| v == want_y), "round {round}: Y sum");
            assert!(od.iter().all(|&v| v == want_d), "round {round}: Dp sum");
            assert!(ox2.iter().all(|&v| v == g.axis_size(Axis::X) as f32));
            let want_members: Vec<f32> =
                g.group_ranks(rank, Axis::Y).iter().map(|&r| r as f32).collect();
            let got: Vec<f32> = gathered.into_iter().flatten().collect();
            assert_eq!(got, want_members, "round {round}: Y gather order");
        }
    });
    for (r, res) in run.results.iter().enumerate() {
        assert!(res.is_ok(), "rank {r} failed");
    }
    let failure = run.finish();
    assert!(failure.is_none(), "coordinator reported {failure:?}");
}

/// Gathered payloads arrive ordered by group index, never arrival order,
/// with per-member lengths allowed to differ.
fn gather_orders_by_group_index(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(1, 2, 2, 1);
    let run = run_world_chaos(b, tag, grid, None, chaos, |rank, w| {
        let payload = vec![rank as f32 + 0.25; rank + 1]; // distinct lengths
        let parts = w.all_gather(rank, Axis::Y, &payload, Precision::Fp32);
        let members = w.grid.group_ranks(rank, Axis::Y);
        assert_eq!(parts.len(), members.len());
        for (p, &m) in parts.iter().zip(&members) {
            assert_eq!(p.len(), m + 1, "member {m} payload length");
            assert!(p.iter().all(|&v| v == m as f32 + 0.25), "member {m} payload");
        }
    });
    for (r, res) in run.results.iter().enumerate() {
        assert!(res.is_ok(), "rank {r} failed");
    }
    assert!(run.finish().is_none());
}

/// bf16 payloads are rounded identically on every backend, and the
/// accounting charges 2 bytes/elem regardless of chunking.
fn bf16_accounting_is_exact(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(1, 2, 1, 1);
    let run = run_world_chaos(b, tag, grid, Some(3), chaos, |rank, w| {
        let mut v: Vec<f32> = (0..10).map(|i| (rank * 10 + i) as f32).collect();
        w.all_reduce(rank, Axis::X, &mut v, Precision::Bf16);
        // bf16 rounding is exact for these small integers
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, (10 + 2 * i) as f32);
        }
    });
    for res in &run.results {
        assert!(res.is_ok());
    }
    let (ops, bytes) = run.total_stats(Axis::X);
    assert_eq!(ops, 2, "one op per contributing rank");
    assert_eq!(bytes, 2 * 10 * 2, "bf16 halves the accounted payload");
    assert!(run.finish().is_none());
}

/// bf16 gathers round every payload once at the source, so all three
/// transports return bit-identical parts (including quieted NaNs and
/// denormals), and the accounting charges 2 bytes/elem.
fn bf16_gather_rounds_identically(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(1, 2, 1, 1);
    let run = run_world_chaos(b, tag, grid, None, chaos, |rank, w| {
        // values that actually round, plus a NaN and an f32 denormal
        let payload = [
            1.0009765625f32 + rank as f32, // needs mantissa rounding
            f32::NAN,
            f32::MIN_POSITIVE / 4.0, // denormal
            -3.14159265f32,
        ];
        let parts = w.all_gather(rank, Axis::X, &payload, Precision::Bf16);
        for (m, part) in parts.iter().enumerate() {
            let src = [
                1.0009765625f32 + m as f32,
                f32::NAN,
                f32::MIN_POSITIVE / 4.0,
                -3.14159265f32,
            ];
            for (j, (&got, &s)) in part.iter().zip(&src).enumerate() {
                let want = scalegnn::util::bf16_round(s);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "member {m} lane {j}: got {got:?} want {want:?}"
                );
            }
        }
    });
    for res in &run.results {
        assert!(res.is_ok());
    }
    let (ops, bytes) = run.total_stats(Axis::X);
    assert_eq!(ops, 2, "one gather per contributing rank");
    assert_eq!(bytes, 2 * 4 * 2, "bf16 halves the accounted gather payload");
    assert!(run.finish().is_none());
}

/// Barriers release all members, carry their own sequence space, and
/// interleave freely with reduces on the same and other axes.
fn barriers_interleave_with_reduces(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(1, 2, 2, 1);
    let run = run_world_chaos(b, tag, grid, None, chaos, |rank, w| {
        for round in 0..5u32 {
            let mut v = vec![rank as f32 + round as f32; 8];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            let want: f32 =
                w.grid.group_ranks(rank, Axis::X).iter().map(|&r| r as f32 + round as f32).sum();
            assert!(v.iter().all(|&x| x == want), "round {round}: X sum");
            w.barrier(rank, Axis::X);
            w.barrier(rank, Axis::Y);
            let mut u = vec![1.0f32; 5];
            w.all_reduce(rank, Axis::Y, &mut u, Precision::Fp32);
            assert!(u.iter().all(|&x| x == 2.0), "round {round}: Y sum");
            w.barrier(rank, Axis::X);
        }
    });
    for (r, res) in run.results.iter().enumerate() {
        assert!(res.is_ok(), "rank {r} failed");
    }
    assert!(run.finish().is_none());
}

/// A world of one rank short-circuits every collective (identity
/// reduce, no-op barrier) without a single transport frame.
fn size1_world_short_circuits(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(1, 1, 1, 1);
    let run = run_world_chaos(b, tag, grid, None, chaos, |rank, w| {
        let mut v = vec![3.5f32; 4];
        w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
        assert_eq!(v, vec![3.5; 4]);
        let parts = w.all_gather(rank, Axis::Dp, &[7.0], Precision::Fp32);
        assert_eq!(parts, vec![vec![7.0]]);
        w.barrier(rank, Axis::Z);
    });
    assert!(run.results[0].is_ok());
    assert_eq!(run.total_stats(Axis::X), (0, 0), "size-1 ops must not be accounted");
    assert!(run.finish().is_none());
}

/// Mismatched reduce lengths poison the group: every member gets an
/// error (not a hang), and the origin is an `all_reduce` failure whose
/// message names the mismatch.
fn length_mismatch_errors_all_ranks(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(1, 2, 1, 1);
    let run = run_world_chaos(b, tag, grid, None, chaos, |rank, w| {
        let mut v = vec![1.0f32; if rank == 0 { 4 } else { 8 }];
        w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
    });
    for (r, res) in run.results.iter().enumerate() {
        assert!(res.is_err(), "rank {r} must fail fast, not hang");
    }
    let origin = run.poison_of(0).expect("world must be poisoned");
    assert_eq!(origin.op, "all_reduce");
    assert!(origin.msg.contains("length mismatch"), "origin: {origin}");
    if let Some(f) = run.finish() {
        assert_eq!(f.op, "all_reduce");
        assert!(f.msg.contains("length mismatch"), "coordinator origin: {f}");
    }
}

/// A reduce and a gather meeting at the same sequence slot is a kind
/// mismatch: clean structured error on every member.
fn kind_mismatch_errors_all_ranks(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(1, 2, 1, 1);
    let run = run_world_chaos(b, tag, grid, None, chaos, |rank, w| {
        if rank == 0 {
            let mut v = vec![1.0f32; 4];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
        } else {
            let _ = w.all_gather(rank, Axis::X, &[1.0, 2.0], Precision::Fp32);
        }
    });
    for (r, res) in run.results.iter().enumerate() {
        assert!(res.is_err(), "rank {r} must fail fast, not hang");
    }
    let origin = run.poison_of(0).expect("world must be poisoned");
    assert!(origin.msg.contains("kind mismatch"), "origin: {origin}");
    if let Some(f) = run.finish() {
        assert!(f.msg.contains("kind mismatch"), "coordinator origin: {f}");
    }
}

/// Ranks 0/1 mismatch on X; ranks 2/3 wait on Y collectives whose peers
/// die — the poison must cascade so the bystanders fail fast too.
fn mismatch_poison_cascades_to_bystanders(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(1, 2, 2, 1);
    let run = run_world_chaos(b, tag, grid, None, chaos, |rank, w| match rank {
        0 => {
            let mut v = vec![1.0f32; 4];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
        }
        1 => {
            let mut v = vec![1.0f32; 8]; // length mismatch vs rank 0
            w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
        }
        _ => {
            // Y groups are {0,2} and {1,3}: peers never arrive
            let mut v = vec![1.0f32; 3];
            w.all_reduce(rank, Axis::Y, &mut v, Precision::Fp32);
        }
    });
    for (r, res) in run.results.iter().enumerate() {
        assert!(res.is_err(), "rank {r} must fail fast, not hang");
    }
    if let Some(f) = run.finish() {
        assert!(f.msg.contains("length mismatch"), "coordinator origin: {f}");
    }
}

/// An injected fault (`CommWorld::fail`) surfaces the SAME origin —
/// rank, `"injected-fault"`, message — on every member of the world,
/// including ranks sharing no group with the victim.
fn injected_fault_reports_origin_everywhere(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(1, 2, 2, 1);
    let run = run_world_chaos(b, tag, grid, None, chaos, |rank, w| {
        if rank == 3 {
            w.fail(rank, "scripted fault: conformance battery");
        }
        let mut v = vec![1.0f32; 4];
        w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
        let mut u = vec![1.0f32; 4];
        w.all_reduce(rank, Axis::Y, &mut u, Precision::Fp32);
    });
    for (r, res) in run.results.iter().enumerate() {
        assert!(res.is_err(), "rank {r} must fail fast, not hang");
    }
    for rank in 0..4 {
        let origin = run.poison_of(rank).unwrap_or_else(|| panic!("rank {rank} not poisoned"));
        assert_eq!(origin.rank, 3, "rank {rank} sees origin rank");
        assert_eq!(origin.op, "injected-fault", "rank {rank} sees origin op");
        assert!(origin.msg.contains("scripted fault"), "rank {rank}: {origin}");
    }
    if let Some(f) = run.finish() {
        assert_eq!((f.rank, f.op), (3, "injected-fault"), "coordinator origin: {f}");
    }
}

/// Regression (the fix this suite rides with): stats / timing /
/// hidden-fraction queries on a poisoned world must return the failure
/// origin as an error — promptly — instead of blocking or answering
/// with misleading half-recorded numbers.
fn poisoned_stats_error_instead_of_blocking(b: BackendSel, tag: &str, chaos: Option<&ChaosSpec>) {
    let grid = Grid4D::new(1, 2, 1, 1);
    let run = run_world_chaos(b, tag, grid, None, chaos, |rank, w| {
        if rank == 1 {
            w.fail(rank, "scripted fault: stats regression");
        }
        let mut v = vec![1.0f32; 4];
        w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
    });
    for res in &run.results {
        assert!(res.is_err());
    }
    for rank in 0..2 {
        let w = &run.worlds[rank];
        let origin = w.check_healthy(rank).expect_err("poisoned world must refuse");
        assert_eq!(origin.op, "injected-fault");
        assert!(w.stats_checked(rank, Axis::X).is_err());
        assert!(w.timing_checked(rank, Axis::X).is_err());
        assert!(w.hidden_fraction_checked(rank, Axis::X).is_err());
        // the unchecked queries still answer (monitoring may poll them);
        // only the checked report path refuses
        let _ = w.stats(Axis::X);
        let _ = w.hidden_fraction(Axis::X);
    }
    let _ = run.finish();
}

// ---------------------------------------------------------------------------
// Cross-backend bitwise identity (in-process harness)
// ---------------------------------------------------------------------------

/// The same multi-round reduce workload produces bit-identical f32
/// results on all three backends: the coordinator's whole-op sum in
/// group-index member order equals the in-process ordered chunk
/// reduction.
#[test]
fn reduction_results_are_bitwise_identical_across_backends() {
    let grid = Grid4D::new(1, 2, 2, 1);
    let collect = |b: BackendSel, tag: &str| -> Vec<Vec<f32>> {
        let out: Arc<Mutex<Vec<Vec<f32>>>> =
            Arc::new(Mutex::new(vec![Vec::new(); grid.world_size()]));
        let sink = out.clone();
        let run = run_world(b, tag, grid, Some(7), move |rank, w| {
            let mut acc = Vec::new();
            for round in 0..4u32 {
                // irrational-ish payloads so float addition order matters
                let mut v: Vec<f32> =
                    (0..23).map(|i| ((rank * 31 + i) as f32).sin() * 0.37 + round as f32).collect();
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                acc.extend_from_slice(&v);
                let mut u: Vec<f32> =
                    (0..11).map(|i| ((rank * 17 + i) as f32).cos() * 1.91).collect();
                w.all_reduce(rank, Axis::Y, &mut u, Precision::Fp32);
                acc.extend_from_slice(&u);
            }
            sink.lock().unwrap()[rank] = acc;
        });
        for res in &run.results {
            assert!(res.is_ok());
        }
        assert!(run.finish().is_none());
        Arc::try_unwrap(out).expect("sole owner").into_inner().unwrap()
    };
    let a = collect(BackendSel::InProc, "bw-ip");
    let b = collect(BackendSel::Uds, "bw-u");
    let c = collect(BackendSel::Tcp, "bw-t");
    for rank in 0..grid.world_size() {
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a[rank]), bits(&b[rank]), "rank {rank}: inproc vs uds");
        assert_eq!(bits(&a[rank]), bits(&c[rank]), "rank {rank}: inproc vs tcp");
    }
}

// ---------------------------------------------------------------------------
// Adversarial wire-format decode
// ---------------------------------------------------------------------------

/// Hand-craft a frame: header (magic, version, type, payload len) +
/// payload + CRC32 trailer over header+payload.
fn raw_frame(version: u16, ftype: u16, payload: &[u8]) -> Vec<u8> {
    let mut b = Vec::new();
    b.extend_from_slice(&WIRE_MAGIC);
    b.extend_from_slice(&version.to_le_bytes());
    b.extend_from_slice(&ftype.to_le_bytes());
    b.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    b.extend_from_slice(payload);
    let crc = crc32(&b);
    b.extend_from_slice(&crc.to_le_bytes());
    b
}

fn encode(msg: &Msg) -> Vec<u8> {
    let mut buf = Vec::new();
    wire::write_msg(&mut buf, msg).expect("encode to Vec");
    buf
}

fn decode_err(bytes: &[u8]) -> WireError {
    let mut r = bytes;
    wire::read_msg(&mut r).expect_err("malformed frame must not decode")
}

#[test]
fn wire_rejects_bad_magic_with_description() {
    let mut bytes = encode(&Msg::Ping);
    bytes[..4].copy_from_slice(b"XXXX");
    let e = decode_err(&bytes);
    assert!(matches!(e, WireError::BadMagic(_)), "got {e:?}");
    assert!(e.to_string().contains("bad frame magic"), "message: {e}");
}

#[test]
fn wire_rejects_wrong_version_with_description() {
    let e = decode_err(&raw_frame(99, 9, &[]));
    assert!(matches!(e, WireError::BadVersion(99)), "got {e:?}");
    assert!(e.to_string().contains("unsupported wire version 99"), "message: {e}");
}

#[test]
fn wire_rejects_unknown_frame_type() {
    let e = decode_err(&raw_frame(wire::WIRE_VERSION, 200, &[]));
    assert!(matches!(e, WireError::BadFrameType(200)), "got {e:?}");
    assert!(e.to_string().contains("unknown frame type"), "message: {e}");
}

#[test]
fn wire_rejects_oversized_payload_before_allocating() {
    // header only — an oversized declared length must be rejected from
    // the 12 header bytes, never by attempting the allocation
    let mut b = Vec::new();
    b.extend_from_slice(&WIRE_MAGIC);
    b.extend_from_slice(&wire::WIRE_VERSION.to_le_bytes());
    b.extend_from_slice(&9u16.to_le_bytes());
    b.extend_from_slice(&((MAX_FRAME_PAYLOAD as u32) + 1).to_le_bytes());
    let e = decode_err(&b);
    assert!(matches!(e, WireError::Oversized(_)), "got {e:?}");
    assert!(e.to_string().contains("exceeds"), "message: {e}");
}

#[test]
fn wire_reports_truncation_position() {
    let full = encode(&Msg::Contribute {
        axis: Axis::Y,
        seq: 3,
        kind: scalegnn::comm::CollKind::Reduce(Precision::Fp32),
        data: vec![1.0; 16],
    });
    // mid-payload cut: past the header, inside the payload bytes
    let e = decode_err(&full[..20]);
    assert!(matches!(e, WireError::Truncated { .. }), "got {e:?}");
    assert!(e.to_string().contains("truncated frame"), "message: {e}");
    // mid-header cut
    let e = decode_err(&full[..5]);
    assert!(matches!(e, WireError::Truncated { .. }), "got {e:?}");
    // clean EOF at a frame boundary is Closed, not Truncated
    let e = decode_err(&[]);
    assert!(matches!(e, WireError::Closed), "got {e:?}");
}

#[test]
fn wire_rejects_corrupt_crc_with_both_values() {
    let mut bytes = encode(&Msg::ReduceResult { axis: Axis::X, seq: 1, data: vec![2.0; 8] });
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    let e = decode_err(&bytes);
    assert!(matches!(e, WireError::BadCrc { .. }), "got {e:?}");
    assert!(e.to_string().contains("CRC mismatch"), "message: {e}");
}

#[test]
fn wire_rejects_payload_with_trailing_garbage() {
    // a Bye frame carries no payload; extra bytes are a malformed frame
    let e = decode_err(&raw_frame(wire::WIRE_VERSION, 10, &[1, 2, 3]));
    assert!(matches!(e, WireError::Malformed(_)), "got {e:?}");
}

#[test]
fn wire_round_trips_every_error_op_name() {
    for op in ["all_reduce", "all_gather", "barrier", "injected-fault", "rank-death", "coordinator-lost"]
    {
        let msg = Msg::Poison { err: CommError::new(2, 9, op, Axis::Dp, "x".to_string()) };
        let bytes = encode(&msg);
        let mut r = &bytes[..];
        let back = wire::read_msg(&mut r).expect("round trip");
        assert_eq!(back, msg, "op {op}");
    }
}

// ---------------------------------------------------------------------------
// Live adversarial: dying peers, garbage servers, bad registrations
// ---------------------------------------------------------------------------

/// A registered rank that disconnects mid-payload poisons the world
/// with a `"rank-death"` origin naming it; the surviving rank gets a
/// clean error, and nobody hangs.
#[test]
fn mid_payload_disconnect_poisons_world_with_rank_death() {
    let grid = Grid4D::new(1, 2, 1, 1);
    let ep = Endpoint::Tcp("127.0.0.1:0".to_string());
    let coord = Coordinator::bind(grid, &ep, CoordConfig::default()).expect("bind");
    let addr = match coord.endpoint() {
        Endpoint::Tcp(a) => a.clone(),
        _ => unreachable!(),
    };
    let coord = coord.spawn();

    // rank 1: a raw client that registers, then sends HALF a contribute
    // frame and vanishes
    let addr1 = addr.clone();
    let liar = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(addr1.as_str()).expect("connect");
        wire::write_msg(&mut s, &Msg::Hello { rank: 1, grid: [1, 2, 1, 1] }).expect("hello");
        match wire::read_msg(&mut s) {
            Ok(Msg::Welcome { .. }) => {}
            other => panic!("expected welcome, got {other:?}"),
        }
        let full = encode(&Msg::Contribute {
            axis: Axis::X,
            seq: 0,
            kind: scalegnn::comm::CollKind::Reduce(Precision::Fp32),
            data: vec![1.0; 64],
        });
        s.write_all(&full[..full.len() / 2]).expect("half frame");
        // drop: mid-payload disconnect
    });

    // rank 0: a real member whose reduce can never complete
    let addr0 = addr.clone();
    let victim = std::thread::spawn(move || {
        let w = CommWorld::connect(grid, 0, &Endpoint::Tcp(addr0)).expect("connect");
        let mut v = vec![1.0f32; 64];
        w.all_reduce(0, Axis::X, &mut v, Precision::Fp32);
    });

    liar.join().expect("raw client");
    assert!(victim.join().is_err(), "surviving rank must error, not hang");
    let failure = coord
        .join()
        .expect("coordinator thread")
        .expect("coordinator run")
        .expect("world must be poisoned");
    assert_eq!(failure.op, "rank-death");
    assert_eq!(failure.rank, 1);
    assert!(failure.msg.contains("rank 1"), "origin must name the dead rank: {failure}");
}

/// Connecting to something that is not a coordinator errors with a
/// descriptive wire failure instead of hanging in the handshake.
#[test]
fn connecting_to_garbage_server_errors_descriptively() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let server = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().expect("accept");
        s.write_all(b"HTTP/1.1 200 OK\r\ncontent-length: 0\r\n\r\n").expect("garbage");
        // keep the connection open so a buggy client would block forever
        std::thread::sleep(Duration::from_millis(300));
    });
    let err = CommWorld::connect(Grid4D::new(1, 2, 1, 1), 0, &Endpoint::Tcp(addr))
        .expect_err("a garbage server must not produce a world");
    let msg = format!("{err:#}");
    assert!(msg.contains("bad frame magic"), "error must describe the frame: {msg}");
    server.join().expect("server thread");
}

/// The coordinator rejects garbage connections and wrong registrations
/// (bad grid, out-of-range rank) while continuing to assemble the world
/// from valid ranks.
#[test]
fn coordinator_rejects_bad_registrations_and_still_assembles() {
    let grid = Grid4D::new(1, 2, 1, 1);
    let ep = Endpoint::Tcp("127.0.0.1:0".to_string());
    let coord = Coordinator::bind(grid, &ep, CoordConfig::default()).expect("bind");
    let addr = match coord.endpoint() {
        Endpoint::Tcp(a) => a.clone(),
        _ => unreachable!(),
    };
    let coord = coord.spawn();

    // three invalid registration attempts, all rejected without
    // disturbing assembly
    {
        let mut s = std::net::TcpStream::connect(addr.as_str()).expect("connect");
        s.write_all(b"GET / HTTP/1.1\r\n\r\n--garbage--").expect("garbage bytes");
    }
    {
        let mut s = std::net::TcpStream::connect(addr.as_str()).expect("connect");
        wire::write_msg(&mut s, &Msg::Hello { rank: 0, grid: [9, 9, 9, 9] }).expect("wrong grid");
    }
    {
        let mut s = std::net::TcpStream::connect(addr.as_str()).expect("connect");
        wire::write_msg(&mut s, &Msg::Hello { rank: 77, grid: [1, 2, 1, 1] })
            .expect("rank out of range");
    }

    let hs: Vec<_> = (0..2)
        .map(|r| {
            let a = addr.clone();
            std::thread::spawn(move || {
                let w = CommWorld::connect(grid, r, &Endpoint::Tcp(a)).expect("valid rank");
                let mut v = vec![r as f32 + 1.0; 6];
                w.all_reduce(r, Axis::X, &mut v, Precision::Fp32);
                assert!(v.iter().all(|&x| x == 3.0));
            })
        })
        .collect();
    for h in hs {
        h.join().expect("valid ranks must train through the noise");
    }
    let failure = coord.join().expect("coordinator thread").expect("coordinator run");
    assert!(failure.is_none(), "world must complete cleanly: {failure:?}");
}

// ---------------------------------------------------------------------------
// Multi-process bitwise identity (real binaries, real OS processes)
// ---------------------------------------------------------------------------

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sgnn-conf-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("create tmp dir");
    d
}

/// The `[[step, loss], ...]` pairs of a report's `loss_curve` from a
/// stats-json document.  f32→JSON→f64→f32 round-trips exactly, so these
/// support bitwise comparisons.
fn loss_curve_of(stats_json: &str) -> Vec<(u64, f32)> {
    let doc = Json::parse(stats_json).expect("stats json parses");
    let curve = doc
        .get("report")
        .and_then(|r| r.get("loss_curve"))
        .and_then(|c| c.as_arr())
        .expect("report.loss_curve");
    curve
        .iter()
        .map(|pair| {
            let s = pair.idx(0).and_then(|v| v.as_usize()).expect("step") as u64;
            let l = pair.idx(1).and_then(|v| v.as_f64()).expect("loss") as f32;
            (s, l)
        })
        .collect()
}

/// Headline: the same `RunSpec` trained over a Unix-socket world across
/// two real OS processes (plus the coordinator binary) produces a
/// loss curve bitwise identical to the in-process threaded run.
#[test]
fn multiprocess_socket_run_is_bitwise_identical_to_inproc() {
    let spec = RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(1, 2, 1, 1)
        .model(16, 2, 0.5)
        .steps(6)
        .lr(5e-3)
        .seed(42);
    let clean = run_silent(&spec).expect("in-process run");
    assert_eq!(clean.loss_curve.len(), 6);

    let dir = tmp_dir("mpbw");
    let spec_path = dir.join("spec.json");
    std::fs::write(&spec_path, spec.to_json().to_string() + "\n").expect("write spec");
    let sock = dir.join("world.sock");

    let coord = std::process::Command::new(env!("CARGO_BIN_EXE_scalegnn-coord"))
        .args(["--grid", "1x2x1x1", "--unix"])
        .arg(&sock)
        .arg("--quiet")
        .spawn()
        .expect("spawn coordinator");

    let children: Vec<_> = (0..2)
        .map(|r| {
            let out = dir.join(format!("stats-r{r}.json"));
            std::process::Command::new(env!("CARGO_BIN_EXE_scalegnn"))
                .args(["run", "--spec"])
                .arg(&spec_path)
                .args(["--transport", &format!("unix:{}", sock.display())])
                .args(["--rank", &r.to_string(), "--quiet", "--stats-json"])
                .arg(&out)
                .spawn()
                .expect("spawn rank")
        })
        .collect();
    for (r, c) in children.into_iter().enumerate() {
        let st = c.wait_with_output().expect("rank wait");
        assert!(st.status.success(), "rank {r} failed: {st:?}");
    }
    let st = coord.wait_with_output().expect("coordinator wait");
    assert!(st.status.success(), "coordinator failed: {st:?}");

    let stats = std::fs::read_to_string(dir.join("stats-r0.json")).expect("rank 0 stats");
    let socket_curve = loss_curve_of(&stats);
    assert_eq!(socket_curve.len(), clean.loss_curve.len());
    for (i, (&(es, el), &(gs, gl))) in clean.loss_curve.iter().zip(&socket_curve).enumerate() {
        assert_eq!(es, gs, "step index {i}");
        assert_eq!(el.to_bits(), gl.to_bits(), "step {es}: in-process {el} vs socket {gl}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
