//! Chaos determinism: the fault-injection schedule is a pure function of
//! `ChaosSpec`, so a faulting rank's failure origin is byte-identical
//! across repeated runs — swept over ten seeds at the transport layer
//! (single-threaded, where no poison race exists by construction) — and
//! a PMM session under destructive chaos either recovers onto the clean
//! loss curve bit for bit or fails with the schedule-stamped origin,
//! identically on every run.  Every multi-threaded case sits under a
//! hard watchdog: a chaos bug may fail a test, never hang it.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use scalegnn::comm::{
    ChaosMode, ChaosSpec, ChaosTransport, CollKind, CommError, FailureKind, InProcTransport,
    Precision, Transport, TransportTuning,
};
use scalegnn::grid::{Axis, Grid4D};
use scalegnn::session::{self, BackendKind, RunReport, RunSpec};

/// The ten sweep seeds: arbitrary but fixed, spread across the u64 range.
fn sweep_seeds() -> [u64; 10] {
    let mut s = [0u64; 10];
    for (i, v) in s.iter_mut().enumerate() {
        *v = 0xC4A0_5EED ^ ((i as u64) * 0x9E37_79B9_7F4A_7C15);
    }
    s
}

/// Run `f` on a helper thread under a hard deadline so an injected fault
/// that slipped past the wait discipline fails the test instead of
/// hanging the suite.
fn with_no_hang_deadline<F: FnOnce() + Send + 'static>(name: &'static str, f: F) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(120)) {
        Ok(()) => h.join().expect("watchdogged test thread"),
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            panic!("{name}: exceeded the 120 s no-hang deadline")
        }
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
            unreachable!("sender dropped without a panic");
        }
    }
}

/// Drive a fresh single-rank chaos transport until the schedule injects a
/// fault; returns the event index and the error.  Single-threaded, so the
/// outcome is exactly the schedule — nothing to race with.
fn first_injected_fault(spec: &ChaosSpec) -> (u64, CommError) {
    let grid = Grid4D::new(1, 1, 1, 1);
    let t = ChaosTransport::new(Box::new(InProcTransport::new(grid, 64)), spec.clone());
    let payload = [1.0f32, 2.0, 3.0];
    let mut out = [0.0f32; 3];
    for event in 0..10_000u64 {
        match t.issue(0, Axis::X, CollKind::Reduce(Precision::Fp32), &payload) {
            Ok(seq) => {
                t.wait_reduce(0, Axis::X, seq, &mut out).expect("un-faulted op completes");
            }
            Err(e) => return (event, e),
        }
    }
    panic!("no injected fault within 10k events at rate {}", spec.rate);
}

#[test]
fn ten_seed_sweep_same_spec_gives_byte_identical_failure_origin() {
    let mut first_events = Vec::new();
    for seed in sweep_seeds() {
        let spec = ChaosSpec::with_modes(seed, 0.35, vec![ChaosMode::Drop]);
        let (n_a, err_a) = first_injected_fault(&spec);
        let (n_b, err_b) = first_injected_fault(&spec);
        assert_eq!(n_a, n_b, "seed {seed}: injection event index must be schedule-determined");
        assert_eq!(err_a, err_b, "seed {seed}: failure origin must be byte-identical");
        assert_eq!(err_a.rank, 0);
        assert_eq!(err_a.seq, 0, "injected faults are not tied to an op slot");
        assert_eq!(err_a.op, "injected-fault");
        assert_eq!(err_a.axis, Axis::X);
        assert_eq!(err_a.kind, FailureKind::Fault);
        assert_eq!(
            err_a.msg,
            format!("chaos drop (seed {seed}, event {n_a})"),
            "the origin message carries the schedule coordinates"
        );
        first_events.push(n_a);
    }
    // and the seed actually selects the schedule: ten seeds must not all
    // agree on where the first fault lands
    first_events.sort_unstable();
    first_events.dedup();
    assert!(first_events.len() >= 2, "every seed injected at the same event: {first_events:?}");
}

#[test]
fn stall_injection_points_are_schedule_determined() {
    // A `Stall` makes the rank go silent until poisoned or until the hard
    // cap expires.  Single-threaded nobody ever poisons it, so the cap is
    // the observable: events where `issue` blocked ~cap long are exactly
    // the schedule's stall events, run after run.
    let cap = Duration::from_millis(40);
    let stalled_events = |spec: &ChaosSpec| -> Vec<u64> {
        let grid = Grid4D::new(1, 1, 1, 1);
        let t = ChaosTransport::new(Box::new(InProcTransport::new(grid, 64)), spec.clone())
            .with_stall_cap(cap);
        let payload = [4.0f32; 8];
        let mut out = [0.0f32; 8];
        let mut stalled = Vec::new();
        for event in 0..48u64 {
            let t0 = Instant::now();
            let seq = t
                .issue(0, Axis::Dp, CollKind::Reduce(Precision::Fp32), &payload)
                .expect("stalls delay, they do not fail");
            if t0.elapsed() >= cap {
                stalled.push(event);
            }
            t.wait_reduce(0, Axis::Dp, seq, &mut out).expect("op completes after the stall");
        }
        stalled
    };
    let spec = ChaosSpec::with_modes(0xBAD_CAFE, 0.25, vec![ChaosMode::Stall]);
    let a = stalled_events(&spec);
    let b = stalled_events(&spec);
    assert!(!a.is_empty(), "rate 0.25 over 48 events must stall at least once");
    assert_eq!(a, b, "stall points must be schedule-determined, not timing-determined");
}

// ---------------------------------------------------------------------------
// Session level: destructive chaos on a two-rank PMM world
// ---------------------------------------------------------------------------

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("scalegnn_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Two ranks, overlap off (issue/wait run in lockstep), snapshot every
/// step, `Drop`-only chaos: the first injection — and therefore the whole
/// run outcome — is a function of the seed alone.
fn chaos_spec(seed: u64, dir: &std::path::Path) -> RunSpec {
    RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(1, 2, 1, 1)
        .model(16, 2, 0.0)
        .steps(8)
        .lr(5e-3)
        .overlap(false)
        .checkpoint(dir.to_path_buf(), 1, 8)
        .tuning(TransportTuning { wait_timeout_ms: Some(2_000), ..Default::default() })
        .chaos(ChaosSpec::with_modes(seed, 0.05, vec![ChaosMode::Drop]))
}

fn assert_bitwise_eq(a: &[(u64, f32)], b: &[(u64, f32)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: curve lengths differ");
    for (&(sa, la), &(sb, lb)) in a.iter().zip(b.iter()) {
        assert_eq!(sa, sb, "{what}: step index diverged");
        assert_eq!(la.to_bits(), lb.to_bits(), "{what}: loss at step {sa}: {la} vs {lb}");
    }
}

/// The schedule-stamped `chaos drop (seed S, event N)` span of an error
/// string — the part that must agree across runs (paths around it, such
/// as the per-run snapshot dir, legitimately differ).
fn origin_span(text: &str) -> &str {
    let start = text.find("chaos drop (").unwrap_or_else(|| {
        panic!("a chaos-injected failure must carry its origin stamp, got: {text}")
    });
    let end = text[start..].find(')').expect("the stamp is parenthesized") + start + 1;
    &text[start..end]
}

fn summarize(report: &RunReport) -> String {
    let f: Vec<String> = report
        .failures
        .iter()
        .map(|f| {
            format!(
                "rank {} seq {} op {} axis {} resumed {:?}: {}",
                f.rank, f.seq, f.op, f.axis, f.resumed_from_step, f.message
            )
        })
        .collect();
    format!("restarts {} failures [{}]", report.restarts, f.join("; "))
}

/// The curve of the same world with chaos disarmed — what every
/// recovered chaos run must land on bit for bit.
fn clean_curve() -> Vec<(u64, f32)> {
    session::run_silent(
        &RunSpec::new(BackendKind::Pmm, "tiny")
            .grid(1, 2, 1, 1)
            .model(16, 2, 0.0)
            .steps(8)
            .lr(5e-3)
            .overlap(false),
    )
    .unwrap()
    .loss_curve
}

#[test]
fn pmm_session_under_drop_chaos_is_run_to_run_deterministic() {
    with_no_hang_deadline("pmm_session_under_drop_chaos_is_run_to_run_deterministic", || {
        let clean = clean_curve();
        for (i, seed) in sweep_seeds().iter().take(3).enumerate() {
            let d1 = tmp_dir(&format!("s{i}_a"));
            let d2 = tmp_dir(&format!("s{i}_b"));
            let r1 = session::run_silent(&chaos_spec(*seed, &d1));
            let r2 = session::run_silent(&chaos_spec(*seed, &d2));
            match (r1, r2) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(summarize(&a), summarize(&b), "seed {seed}: reports diverged");
                    assert_bitwise_eq(&a.loss_curve, &b.loss_curve, "chaos repeat");
                    assert_bitwise_eq(&clean, &a.loss_curve, "chaos vs clean");
                }
                (Err(a), Err(b)) => {
                    // died before the first snapshot: fatal, but with the
                    // same schedule-stamped origin on both runs
                    let (a, b) = (format!("{a:#}"), format!("{b:#}"));
                    assert_eq!(origin_span(&a), origin_span(&b), "seed {seed}: origins diverged");
                    assert!(a.contains("injected-fault"), "origin op must survive: {a}");
                }
                (a, b) => panic!(
                    "seed {seed}: outcome must be seed-determined, got {:?} then {:?}",
                    a.map(|r| summarize(&r)),
                    b.map(|r| summarize(&r)),
                ),
            }
            let _ = std::fs::remove_dir_all(&d1);
            let _ = std::fs::remove_dir_all(&d2);
        }
    });
}

#[test]
fn pmm_session_recovered_from_chaos_lands_on_the_clean_curve_bitwise() {
    with_no_hang_deadline("pmm_session_recovered_from_chaos_lands_on_the_clean_curve_bitwise", || {
        let clean = clean_curve();
        // Which step the first injection hits is a fixed function of the
        // seed, but not one this test can predict — so probe candidate
        // seeds (deterministically, in order) until one survives past the
        // first snapshot and recovers.  A fatal probe (injection before
        // step 1) is a legitimate outcome covered above, not a recovery.
        for probe in 0..16u64 {
            let seed = 0x0DD5_EED5 + probe * 0x1_0001;
            let dir = tmp_dir(&format!("probe_{probe}"));
            let outcome = session::run_silent(&chaos_spec(seed, &dir));
            let _ = std::fs::remove_dir_all(&dir);
            let report = match outcome {
                Ok(r) if !r.failures.is_empty() => r,
                // fatal, or chaos never fired within 8 steps: next seed
                _ => continue,
            };
            let f = &report.failures[0];
            assert_eq!(f.op, "injected-fault", "origin op: {}", f.message);
            assert!(
                f.message.contains(&format!("chaos drop (seed {seed}, event ")),
                "origin must be schedule-stamped: {}",
                f.message
            );
            assert_eq!(report.restarts, 1, "chaos is disarmed on replay");
            assert!(f.resumed_from_step.is_some(), "recovery names its snapshot step");
            assert_bitwise_eq(&clean, &report.loss_curve, "recovered chaos vs clean");
            // and the recovery itself is reproducible
            let dir = tmp_dir("probe_again");
            let again = session::run_silent(&chaos_spec(seed, &dir)).unwrap();
            let _ = std::fs::remove_dir_all(&dir);
            assert_eq!(summarize(&report), summarize(&again), "seed {seed}: reports diverged");
            return;
        }
        panic!("no probe seed recovered: every injection landed before the first snapshot");
    });
}
