//! Tier-1 enforcement of the repo's static-analysis pass: `pallas-lint`
//! runs over the real `rust/src/**` tree and the build fails on any
//! violation of the determinism / panic-free-boundary / SAFETY /
//! hot-path-allocation / lock-order disciplines (see
//! `tools/pallas-lint` and ARCHITECTURE.md §Static analysis).
//!
//! To silence a finding you must either fix it or add an explicit
//! `// lint: allow(rule-id) — justification` escape on the preceding
//! line; bare allows are themselves diagnostics.

use std::path::Path;

#[test]
fn source_tree_has_zero_lint_diagnostics() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let cfg = pallas_lint::Config::repo();
    let report = pallas_lint::lint_tree(&root, &cfg).expect("linting rust/src");
    assert!(
        !report.allows.is_empty(),
        "the tree is known to carry justified allows; an empty list means the \
         allow parser regressed"
    );
    assert!(
        report.diagnostics.is_empty(),
        "pallas-lint found {} violation(s) in rust/src:\n{}\nfix the code or add a \
         justified `// lint: allow(rule-id) — why` on the preceding line",
        report.diagnostics.len(),
        report.render_text()
    );
}

#[test]
fn every_allow_in_the_tree_is_used_and_justified() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let cfg = pallas_lint::Config::repo();
    let report = pallas_lint::lint_tree(&root, &cfg).expect("linting rust/src");
    for a in &report.allows {
        assert!(
            !a.justification.is_empty(),
            "{}:{} allow({}) has an empty justification",
            a.file,
            a.line,
            a.rule
        );
        assert!(
            a.used,
            "{}:{} allow({}) suppresses nothing — stale escapes must be removed",
            a.file,
            a.line,
            a.rule
        );
    }
}
