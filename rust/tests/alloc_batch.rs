//! Integration: steady-state mini-batch construction must be
//! allocation-free — the sampling-fast-path acceptance bar.  After a short
//! warmup (buffer capacities grow to their steady sizes), a recycled
//! `BatchMaker::make()` and a workspace `sample_and_induce_into` must
//! average ~zero heap allocations per step.
//!
//! A counting global allocator measures exact allocation counts.  The test
//! pins `PALLAS_THREADS=1` before any pool use so the serial inline path is
//! exercised and thread-spawn allocations cannot pollute the counts (this
//! file contains exactly one test, so there is no env-mutation race).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_batch_construction_is_allocation_free() {
    std::env::set_var("PALLAS_THREADS", "1");

    use std::sync::Arc;

    use scalegnn::graph::datasets;
    use scalegnn::sampling::{
        sample_and_induce_into, InduceWorkspace, MiniBatch, SamplerKind, UniformVertexSampler,
    };
    use scalegnn::trainer::batch::BatchMaker;

    let d = Arc::new(datasets::load("tiny").unwrap());

    // --- full BatchMaker::make with shell recycling ---
    let mut maker = BatchMaker::new(d.clone(), SamplerKind::ScaleGnnUniform, 64, 2048, 2, 9);
    // warmup: capacities grow to the steady-state maximum
    for step in 0..8u64 {
        let b = maker.make(step);
        maker.recycle(b);
    }
    let before = allocs();
    let steps = 20u64;
    for step in 8..8 + steps {
        let b = maker.make(step);
        maker.recycle(b);
    }
    let per_step = (allocs() - before) as f64 / steps as f64;
    // ~0: an occasional capacity regrow on an unusually dense batch is
    // amortized away; anything structural (per-step Vec/Box/HashMap churn)
    // lands far above 1
    assert!(
        per_step < 1.0,
        "BatchMaker::make allocates {per_step:.2}x per step in steady state"
    );

    // --- raw workspace induction (with transpose, the OOC/PMM shape) ---
    let sampler = UniformVertexSampler::new(d.n, 64, 11);
    let mut ws = InduceWorkspace::new();
    let mut mb = MiniBatch::default();
    for step in 0..8u64 {
        sample_and_induce_into(&d.adj, &sampler, step, true, &mut ws, &mut mb);
    }
    let before = allocs();
    for step in 8..8 + steps {
        sample_and_induce_into(&d.adj, &sampler, step, true, &mut ws, &mut mb);
    }
    let per_step = (allocs() - before) as f64 / steps as f64;
    assert!(
        per_step < 1.0,
        "sample_and_induce_into allocates {per_step:.2}x per step in steady state"
    );
}
