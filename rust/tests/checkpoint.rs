//! Checkpoint-subsystem tests: the binary format round-trips bitwise,
//! every torn-write mode (truncation, payload corruption, stale version)
//! is detected with a descriptive error — never a panic — and the
//! discovery path falls back to the previous valid snapshot.

use std::path::PathBuf;

use scalegnn::checkpoint::{
    self, CheckpointManager, CheckpointPolicy, CorruptKind, Snapshot,
};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("scalegnn_ckpt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn sample_snapshot(step: u64) -> Snapshot {
    let tensors = vec![vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE], vec![3.0; 7]];
    let m = vec![vec![0.1f32, 0.2, 0.3, 0.4], vec![-0.5; 7]];
    let v = vec![vec![0.01f32, 0.02, 0.03, 0.04], vec![0.5; 7]];
    Snapshot::from_flat(step, 42, 0xFEED, tensors, m, v, step as f32)
}

#[test]
fn snapshot_roundtrips_bitwise_through_a_file() {
    let dir = tmp_dir("roundtrip");
    let snap = sample_snapshot(7);
    let path = checkpoint::save(&dir, "t", &snap).unwrap();
    assert_eq!(path, checkpoint::path_for(&dir, "t", 7));
    let back = checkpoint::load(&path).unwrap();
    assert_eq!(back, snap, "decode(encode(s)) must be identical");
    // f32 payloads survive bit-exactly, not just approximately
    for (a, b) in snap.tensors.iter().zip(&back.tensors) {
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_torn_write_mode_is_a_clean_descriptive_error() {
    for (kind, needle) in [
        (CorruptKind::Truncate, "truncated"),
        (CorruptKind::FlipPayloadBit, "checksum"),
        (CorruptKind::StaleVersion, "version"),
    ] {
        let dir = tmp_dir(&format!("torn_{needle}"));
        checkpoint::save(&dir, "t", &sample_snapshot(3)).unwrap();
        let path = checkpoint::corrupt_newest(&dir, "t", kind).unwrap();
        let err = checkpoint::load(&path).unwrap_err().to_string();
        assert!(
            err.contains(needle),
            "{kind:?} should report '{needle}', got: {err}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn discovery_skips_corrupt_newest_and_falls_back() {
    let dir = tmp_dir("fallback");
    checkpoint::save(&dir, "t", &sample_snapshot(2)).unwrap();
    checkpoint::save(&dir, "t", &sample_snapshot(4)).unwrap();
    checkpoint::corrupt_newest(&dir, "t", CorruptKind::FlipPayloadBit).unwrap();

    let (steps, warnings) = checkpoint::valid_steps(&dir, "t");
    assert_eq!(steps, vec![2], "the corrupt step-4 file must be skipped");
    assert!(!warnings.is_empty(), "skipping must be reported, not silent");

    let (found, _) = checkpoint::latest_valid(&dir, "t");
    let (path, snap) = found.expect("the previous valid snapshot survives");
    assert_eq!(snap.step, 2);
    assert_eq!(path, checkpoint::path_for(&dir, "t", 2));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unrelated_files_do_not_confuse_discovery() {
    let dir = tmp_dir("unrelated");
    checkpoint::save(&dir, "t", &sample_snapshot(1)).unwrap();
    std::fs::write(dir.join("notes.txt"), "not a checkpoint").unwrap();
    std::fs::write(dir.join("other-step000000000009.ckpt"), "different tag").unwrap();
    let (steps, _) = checkpoint::valid_steps(&dir, "t");
    assert_eq!(steps, vec![1]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manager_enforces_cadence_and_retention() {
    let dir = tmp_dir("manager");
    let mgr = CheckpointManager::new(CheckpointPolicy::new(dir.clone(), 2, 2), "t");
    // every_steps = 2 saves after steps 1, 3, 5, ... (0-based)
    assert!(!mgr.should_save(0));
    assert!(mgr.should_save(1));
    assert!(!mgr.should_save(2));
    assert!(mgr.should_save(3));
    for step in [2u64, 4, 6, 8] {
        mgr.save(&sample_snapshot(step)).unwrap();
    }
    let (steps, warnings) = mgr.valid_steps();
    assert_eq!(steps, vec![6, 8], "keep = 2 retains only the newest two");
    assert!(warnings.is_empty());
    let (found, _) = mgr.latest();
    assert_eq!(found.unwrap().1.step, 8);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn check_hash_refuses_a_different_run_configuration() {
    let snap = sample_snapshot(5);
    snap.check_hash(0xFEED, "test").unwrap();
    let err = snap.check_hash(0xBEEF, "test").unwrap_err().to_string();
    assert!(err.contains("hash mismatch"), "{err}");
}
