//! Integration: the PJRT-executed artifacts must reproduce the exact
//! trajectories recorded by JAX at AOT time (`artifacts/golden.json`).
//! This pins the whole three-layer contract: Pallas kernels -> JAX model ->
//! HLO text -> xla-crate PJRT execution from Rust.

use std::path::PathBuf;

use scalegnn::runtime::{lit_f32, lit_i32, lit_u32, scalar_f32, to_f32, Runtime};
use scalegnn::util::json::Json;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Skip when the AOT artifacts are absent or no real PJRT backend is
/// linked (offline/stub build) — the assertions below are unchanged and
/// run in full whenever `make artifacts` has produced the golden files.
fn artifacts_available() -> bool {
    let ok = scalegnn::runtime::pjrt_artifacts_available(&artifacts_dir())
        && artifacts_dir().join("golden.json").exists();
    if !ok {
        eprintln!("skipping: PJRT artifacts/backend not available");
    }
    ok
}

fn load_golden() -> Json {
    let text = std::fs::read_to_string(artifacts_dir().join("golden.json"))
        .expect("run `make artifacts` first");
    Json::parse(&text).unwrap()
}

#[test]
fn train_step_tiny_reproduces_jax_losses() {
    if !artifacts_available() {
        return;
    }
    let g = load_golden();
    let rt = Runtime::open(&artifacts_dir()).unwrap();
    let meta = rt.model("tiny").unwrap().clone();
    let exe = rt.load("train_step_tiny").unwrap();

    let b = meta.batch;
    let e = meta.edge_cap;
    let src: Vec<i32> = g.get("src").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_f64().unwrap() as i32).collect();
    let dst: Vec<i32> = g.get("dst").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_f64().unwrap() as i32).collect();
    let val = g.get("val").unwrap().as_f32_vec().unwrap();
    let x = g.get("x").unwrap().as_f32_vec().unwrap();
    let y: Vec<i32> = g
        .get("y")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect();
    let wm = g.get("wmask").unwrap().as_f32_vec().unwrap();
    let lr = g.get("lr").unwrap().as_f64().unwrap() as f32;
    let steps = g.get("steps").unwrap().as_usize().unwrap();
    let want_losses = g.get("losses").unwrap().as_f32_vec().unwrap();
    let want_accs = g.get("accs").unwrap().as_f32_vec().unwrap();

    // initial state
    let init: Vec<Vec<f32>> = g
        .get("init_params")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.as_f32_vec().unwrap())
        .collect();
    let np = meta.n_params;
    assert_eq!(init.len(), np);
    let mut params = init;
    let mut m: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut v = m.clone();
    let mut t = 0.0f32;

    let keys = g.get("keys").unwrap().as_arr().unwrap();
    for step in 0..steps {
        let key: Vec<u32> = keys[step]
            .as_arr()
            .unwrap()
            .iter()
            .map(|k| k.as_f64().unwrap() as u32)
            .collect();
        let mut inputs = vec![
            lit_i32(&src, &[e]).unwrap(),
            lit_i32(&dst, &[e]).unwrap(),
            lit_f32(&val, &[e]).unwrap(),
            lit_f32(&x, &[b, meta.d_in]).unwrap(),
            lit_i32(&y, &[b]).unwrap(),
            lit_f32(&wm, &[b]).unwrap(),
            lit_u32(&key, &[2]).unwrap(),
            xla::Literal::scalar(lr),
            xla::Literal::scalar(t),
        ];
        for group in [&params, &m, &v] {
            for (data, shape) in group.iter().zip(&meta.param_shapes) {
                inputs.push(lit_f32(data, shape).unwrap());
            }
        }
        let outs = exe.run(&inputs).unwrap();
        let loss = scalar_f32(&outs[0]).unwrap();
        let acc = scalar_f32(&outs[1]).unwrap();
        t = scalar_f32(&outs[2]).unwrap();
        assert!(
            (loss - want_losses[step]).abs() < 2e-4,
            "step {step}: loss {loss} vs jax {}",
            want_losses[step]
        );
        assert!(
            (acc - want_accs[step]).abs() < 1e-3,
            "step {step}: acc {acc} vs jax {}",
            want_accs[step]
        );
        for i in 0..np {
            params[i] = to_f32(&outs[3 + i]).unwrap();
            m[i] = to_f32(&outs[3 + np + i]).unwrap();
            v[i] = to_f32(&outs[3 + 2 * np + i]).unwrap();
        }
    }

    // final state cross-checks
    let want_sum = g.get("final_param0_sum").unwrap().as_f64().unwrap() as f32;
    let got_sum: f32 = params[0].iter().sum();
    assert!(
        (got_sum - want_sum).abs() < 2e-3 * (1.0 + want_sum.abs()),
        "param0 sum {got_sum} vs jax {want_sum}"
    );

    // eval logits row 0
    let ev = rt.load("eval_logits_tiny").unwrap();
    let mut einputs = vec![
        lit_i32(&src, &[e]).unwrap(),
        lit_i32(&dst, &[e]).unwrap(),
        lit_f32(&val, &[e]).unwrap(),
        lit_f32(&x, &[b, meta.d_in]).unwrap(),
    ];
    for (data, shape) in params.iter().zip(&meta.param_shapes) {
        einputs.push(lit_f32(data, shape).unwrap());
    }
    let eouts = ev.run(&einputs).unwrap();
    let logits = to_f32(&eouts[0]).unwrap();
    let want_row0 = g.get("final_logits_row0").unwrap().as_f32_vec().unwrap();
    for (j, (&got, &want)) in logits[..meta.d_out].iter().zip(&want_row0).enumerate() {
        assert!(
            (got - want).abs() < 5e-3 * (1.0 + want.abs()),
            "logit[0][{j}] {got} vs jax {want}"
        );
    }
}

#[test]
fn grad_plus_adam_artifacts_match_fused_step() {
    if !artifacts_available() {
        return;
    }
    let g = load_golden();
    let rt = Runtime::open(&artifacts_dir()).unwrap();
    let meta = rt.model("tiny").unwrap().clone();
    let fused = rt.load("train_step_tiny").unwrap();
    let grad = rt.load("grad_step_tiny").unwrap();
    let adam = rt.load("adam_apply_tiny").unwrap();

    let b = meta.batch;
    let e = meta.edge_cap;
    let src: Vec<i32> = g.get("src").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_f64().unwrap() as i32).collect();
    let dst: Vec<i32> = g.get("dst").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_f64().unwrap() as i32).collect();
    let val = g.get("val").unwrap().as_f32_vec().unwrap();
    let x = g.get("x").unwrap().as_f32_vec().unwrap();
    let y: Vec<i32> = g
        .get("y")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect();
    let wm = g.get("wmask").unwrap().as_f32_vec().unwrap();
    let params: Vec<Vec<f32>> = g
        .get("init_params")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.as_f32_vec().unwrap())
        .collect();
    let np = meta.n_params;
    let zeros: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let key = [1000u32, 0u32];
    let lr = 1e-2f32;

    let batch_lits = |extra: bool| -> Vec<xla::Literal> {
        let mut v = vec![
            lit_i32(&src, &[e]).unwrap(),
            lit_i32(&dst, &[e]).unwrap(),
            lit_f32(&val, &[e]).unwrap(),
            lit_f32(&x, &[b, meta.d_in]).unwrap(),
            lit_i32(&y, &[b]).unwrap(),
            lit_f32(&wm, &[b]).unwrap(),
            lit_u32(&key, &[2]).unwrap(),
        ];
        if extra {
            v.push(xla::Literal::scalar(lr));
            v.push(xla::Literal::scalar(0.0f32));
        }
        v
    };

    // fused
    let mut fin = batch_lits(true);
    for group in [&params, &zeros, &zeros] {
        for (data, shape) in group.iter().zip(&meta.param_shapes) {
            fin.push(lit_f32(data, shape).unwrap());
        }
    }
    let fouts = fused.run(&fin).unwrap();

    // decomposed
    let mut gin = batch_lits(false);
    for (data, shape) in params.iter().zip(&meta.param_shapes) {
        gin.push(lit_f32(data, shape).unwrap());
    }
    let gouts = grad.run(&gin).unwrap();
    assert!(
        (scalar_f32(&gouts[0]).unwrap() - scalar_f32(&fouts[0]).unwrap()).abs() < 1e-5,
        "grad_step loss != fused loss"
    );
    let grads: Vec<Vec<f32>> = (0..np).map(|i| to_f32(&gouts[2 + i]).unwrap()).collect();
    let mut ain = vec![xla::Literal::scalar(lr), xla::Literal::scalar(0.0f32)];
    for group in [&params, &grads, &zeros, &zeros] {
        for (data, shape) in group.iter().zip(&meta.param_shapes) {
            ain.push(lit_f32(data, shape).unwrap());
        }
    }
    let aouts = adam.run(&ain).unwrap();
    for i in 0..np {
        let pa = to_f32(&aouts[1 + i]).unwrap();
        let pf = to_f32(&fouts[3 + i]).unwrap();
        let max_diff = pa
            .iter()
            .zip(&pf)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-5, "param {i} decomposed vs fused diff {max_diff}");
    }
}

#[test]
fn fused_update_artifact_matches_rust_reference() {
    if !artifacts_available() {
        return;
    }
    let rt = Runtime::open(&artifacts_dir()).unwrap();
    let exe = rt.load("fused_update_256x64").unwrap();
    let mut rng = scalegnn::util::rng::Rng::new(77);
    let h = scalegnn::tensor::Mat::randn(256, 64, &mut rng, 1.0);
    let w = scalegnn::tensor::Mat::randn(64, 64, &mut rng, 0.3);
    let gsc: Vec<f32> = (0..64).map(|_| rng.uniform(0.5, 1.5)).collect();
    let res = scalegnn::tensor::Mat::randn(256, 64, &mut rng, 1.0);
    let mask: Vec<f32> = (0..256 * 64)
        .map(|_| if rng.f32() < 0.5 { 2.0 } else { 0.0 })
        .collect();

    let outs = exe
        .run(&[
            lit_f32(&h.data, &[256, 64]).unwrap(),
            lit_f32(&w.data, &[64, 64]).unwrap(),
            lit_f32(&gsc, &[64]).unwrap(),
            lit_f32(&res.data, &[256, 64]).unwrap(),
            lit_f32(&mask, &[256, 64]).unwrap(),
        ])
        .unwrap();
    let got = to_f32(&outs[0]).unwrap();

    // rust oracle: relu(rmsnorm(h@w)*g)*mask + res
    let xc = h.matmul(&w);
    let (xn, _) = scalegnn::tensor::rmsnorm(&xc, &gsc, 1e-6);
    let mut want = xn.relu();
    for (i, v) in want.data.iter_mut().enumerate() {
        *v = *v * mask[i] + res.data[i];
    }
    let max_diff = got
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "fused_update artifact vs rust oracle: {max_diff}");
}


#[test]
fn dense_variant_artifact_matches_sparse_losses() {
    if !artifacts_available() {
        return;
    }
    // tiny_dense keeps the B x B Pallas dense-SpMM schedule; on the same
    // batch it must produce the same loss as the sparse lowering.
    let g = load_golden();
    let rt = Runtime::open(&artifacts_dir()).unwrap();
    let meta = rt.model("tiny_dense").unwrap().clone();
    let exe = rt.load("train_step_tiny_dense").unwrap();
    let b = meta.batch;
    let a = g.get("a").unwrap().as_f32_vec().unwrap();
    let x = g.get("x").unwrap().as_f32_vec().unwrap();
    let y: Vec<i32> = g.get("y").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_f64().unwrap() as i32).collect();
    let wm = g.get("wmask").unwrap().as_f32_vec().unwrap();
    let key: Vec<u32> = g.get("keys").unwrap().idx(0).unwrap().as_arr().unwrap()
        .iter().map(|k| k.as_f64().unwrap() as u32).collect();
    let params: Vec<Vec<f32>> = g.get("init_params").unwrap().as_arr().unwrap()
        .iter().map(|p| p.as_f32_vec().unwrap()).collect();
    let zeros: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut inputs = vec![
        lit_f32(&a, &[b, b]).unwrap(),
        lit_f32(&x, &[b, meta.d_in]).unwrap(),
        lit_i32(&y, &[b]).unwrap(),
        lit_f32(&wm, &[b]).unwrap(),
        lit_u32(&key, &[2]).unwrap(),
        xla::Literal::scalar(g.get("lr").unwrap().as_f64().unwrap() as f32),
        xla::Literal::scalar(0.0f32),
    ];
    for group in [&params, &zeros, &zeros] {
        for (data, shape) in group.iter().zip(&meta.param_shapes) {
            inputs.push(lit_f32(data, shape).unwrap());
        }
    }
    let outs = exe.run(&inputs).unwrap();
    let loss = scalar_f32(&outs[0]).unwrap();
    let want = g.get("losses").unwrap().as_f32_vec().unwrap()[0];
    assert!(
        (loss - want).abs() < 2e-4,
        "dense variant loss {loss} vs sparse/jax {want}"
    );
}
