//! Fixture self-tests: every rule is proven *live* (its firing snippet
//! produces diagnostics, and disabling the rule silences them) and
//! *precise* (its near-miss snippet stays clean).  Plus the allow
//! round-trip and the JSON shape pin.

use pallas_lint::{lint_sources, Config, Report};

fn run(path: &str, src: &str, cfg: &Config) -> Report {
    lint_sources(&[(path.to_string(), src.to_string())], cfg)
}

/// (rule, virtual path placing the fixture in the rule's scope, fire, clean)
fn cases() -> Vec<(&'static str, &'static str, &'static str, &'static str)> {
    vec![
        (
            "safety-comment",
            "tensor/simd.rs",
            include_str!("../fixtures/safety_comment_fire.rs"),
            include_str!("../fixtures/safety_comment_clean.rs"),
        ),
        (
            "panic-free-boundary",
            "comm/wire.rs",
            include_str!("../fixtures/panic_free_fire.rs"),
            include_str!("../fixtures/panic_free_clean.rs"),
        ),
        (
            "determinism-ordering",
            "comm/coord.rs",
            include_str!("../fixtures/ordering_fire.rs"),
            include_str!("../fixtures/ordering_clean.rs"),
        ),
        (
            "determinism-fma",
            "tensor/kernel.rs",
            include_str!("../fixtures/fma_fire.rs"),
            include_str!("../fixtures/fma_clean.rs"),
        ),
        (
            "hot-path-alloc",
            "tensor/gemm.rs",
            include_str!("../fixtures/hot_alloc_fire.rs"),
            include_str!("../fixtures/hot_alloc_clean.rs"),
        ),
        (
            "lock-order",
            "comm/inproc.rs",
            include_str!("../fixtures/lock_order_fire.rs"),
            include_str!("../fixtures/lock_order_clean.rs"),
        ),
        (
            "unbounded-wait",
            "comm/socket.rs",
            include_str!("../fixtures/unbounded_wait_fire.rs"),
            include_str!("../fixtures/unbounded_wait_clean.rs"),
        ),
    ]
}

#[test]
fn every_rule_fires_on_its_fixture() {
    for (rule, path, fire, _clean) in cases() {
        let r = run(path, fire, &Config::repo());
        assert!(
            r.diagnostics.iter().any(|d| d.rule == rule),
            "{rule}: firing fixture produced no {rule} diagnostic; got {:?}",
            r.diagnostics
        );
        assert!(
            r.diagnostics.iter().all(|d| d.rule == rule),
            "{rule}: firing fixture tripped other rules too: {:?}",
            r.diagnostics
        );
    }
}

#[test]
fn every_rule_goes_silent_when_disabled() {
    // proves each rule is live: the diagnostics of the firing fixture come
    // from that rule's checker, not from some other path
    for (rule, path, fire, _clean) in cases() {
        let r = run(path, fire, &Config::repo().disable(rule));
        assert!(
            r.diagnostics.is_empty(),
            "{rule}: disabling the rule should silence its fixture, got {:?}",
            r.diagnostics
        );
    }
}

#[test]
fn every_near_miss_stays_clean() {
    for (rule, path, _fire, clean) in cases() {
        let r = run(path, clean, &Config::repo());
        assert!(
            r.diagnostics.is_empty(),
            "{rule}: near-miss fixture must not fire, got {:?}",
            r.diagnostics
        );
    }
}

#[test]
fn out_of_scope_path_silences_scoped_rules() {
    // the same firing source outside the rule's module scope is clean
    // (safety-comment and hot-path-alloc are tree-wide, so skip them here)
    for (rule, _path, fire, _clean) in cases() {
        if rule == "safety-comment" || rule == "hot-path-alloc" {
            continue;
        }
        let r = run("session/spec.rs", fire, &Config::repo());
        assert!(
            r.diagnostics.iter().all(|d| d.rule != rule),
            "{rule}: must not fire outside its module scope, got {:?}",
            r.diagnostics
        );
    }
}

#[test]
fn allow_roundtrip_suppresses_and_surfaces() {
    let src = "\
fn decode(b: &[u8]) -> u32 {
    // lint: allow(panic-free-boundary) — length was validated two lines up
    let arr: [u8; 4] = b[..4].try_into().unwrap();
    u32::from_le_bytes(arr)
}
";
    let r = run("comm/wire.rs", src, &Config::repo());
    assert!(r.diagnostics.is_empty(), "justified allow must suppress: {:?}", r.diagnostics);
    assert_eq!(r.allows.len(), 1);
    let a = &r.allows[0];
    assert_eq!(a.rule, "panic-free-boundary");
    assert_eq!(a.line, 2);
    assert!(a.used, "the allow must be marked used");
    assert_eq!(a.justification, "length was validated two lines up");

    // without the justification the allow is inert AND reported
    let bare = src.replace(" — length was validated two lines up", "");
    let r = run("comm/wire.rs", &bare, &Config::repo());
    assert!(r.diagnostics.iter().any(|d| d.rule == "bad-allow"));
    assert!(r.diagnostics.iter().any(|d| d.rule == "panic-free-boundary"));
    assert!(r.allows.is_empty());

    // an allow for the wrong rule does not suppress
    let wrong = src.replace("panic-free-boundary", "determinism-fma");
    let r = run("comm/wire.rs", &wrong, &Config::repo());
    assert!(r.diagnostics.iter().any(|d| d.rule == "panic-free-boundary"));
    assert_eq!(r.allows.len(), 1);
    assert!(!r.allows[0].used, "a mismatched allow must be surfaced as unused");
}

#[test]
fn json_shape_is_stable() {
    let src = "\
fn f(x: f32) -> f32 {
    // lint: allow(determinism-fma) — reference path, compared against the oracle
    x.mul_add(2.0, 1.0)
}
fn g(x: f32) -> f32 {
    x.mul_add(2.0, 1.0)
}
";
    let r = run("tensor/oracle.rs", src, &Config::repo());
    let expected = concat!(
        "{\"version\":1,\"diagnostics\":[",
        "{\"file\":\"tensor/oracle.rs\",\"line\":6,\"rule\":\"determinism-fma\",",
        "\"message\":\"`mul_add` fuses multiply and add — the bitwise kernel discipline ",
        "requires separate mul + add so SIMD and scalar paths round identically\"}",
        "],\"allows\":[",
        "{\"file\":\"tensor/oracle.rs\",\"line\":2,\"rule\":\"determinism-fma\",",
        "\"justification\":\"reference path, compared against the oracle\",\"used\":true}",
        "]}"
    );
    assert_eq!(r.to_json(), expected);
}

#[test]
fn text_rendering_is_file_line_rule_message() {
    let r = run("tensor/k.rs", "fn f(x: f32) -> f32 { x.mul_add(2.0, 1.0) }\n", &Config::repo());
    assert_eq!(r.diagnostics.len(), 1);
    let line = r.diagnostics[0].render();
    assert!(
        line.starts_with("tensor/k.rs:1 determinism-fma: "),
        "text format must be file:line rule-id: message, got {line}"
    );
}

#[test]
fn diagnostics_are_sorted_and_deterministic() {
    let fire = include_str!("../fixtures/panic_free_fire.rs");
    let files = vec![
        ("comm/wire.rs".to_string(), fire.to_string()),
        ("comm/coord.rs".to_string(), fire.to_string()),
    ];
    let a = lint_sources(&files, &Config::repo());
    let b = lint_sources(&files, &Config::repo());
    assert_eq!(a.to_json(), b.to_json());
    let mut sorted = a.diagnostics.clone();
    sorted.sort_by(|x, y| (&x.file, x.line).cmp(&(&y.file, y.line)));
    assert_eq!(
        a.diagnostics.iter().map(|d| (&d.file, d.line)).collect::<Vec<_>>(),
        sorted.iter().map(|d| (&d.file, d.line)).collect::<Vec<_>>(),
        "diagnostics must come out sorted by (file, line)"
    );
}
