// Fixture: unwrap/expect/panic!/unreachable! in a boundary module must fire.
pub fn decode(b: &[u8]) -> u32 {
    if b.len() < 4 {
        panic!("short buffer");
    }
    let arr: [u8; 4] = b[..4].try_into().unwrap();
    u32::from_le_bytes(arr)
}

pub fn classify(tag: u8) -> &'static str {
    match tag {
        0 => "reduce",
        1 => "gather",
        _ => unreachable!("tag was validated"),
    }
}

pub fn first(v: &[u32]) -> u32 {
    *v.first().expect("non-empty")
}
