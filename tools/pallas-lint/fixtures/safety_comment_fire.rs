// Fixture: `unsafe` without a `// SAFETY:` comment must fire.
pub fn widen(src: &[u16], dst: &mut [f32]) {
    unsafe {
        std::ptr::copy_nonoverlapping(src.as_ptr(), dst.as_mut_ptr() as *mut u16, src.len());
    }
}
