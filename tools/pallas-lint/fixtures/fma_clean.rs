// Fixture near-miss: separate mul + add, mul_add mentioned in comments,
// and identifiers merely containing "mul_add" must NOT fire.
pub fn axpy(acc: &mut [f32], a: f32, b: &[f32]) {
    for (c, &x) in acc.iter_mut().zip(b) {
        // no x.mul_add(a, *c) here: separate mul then add rounds like the
        // scalar oracle
        let prod = x * a;
        *c += prod;
    }
}

pub fn accumulate_matmul_adds_on_top(acc: &mut [f32], delta: &[f32]) {
    for (c, &d) in acc.iter_mut().zip(delta) {
        *c += d;
    }
}
