// Fixture near-miss: keyed HashMap access, iteration over Vec/BTreeMap,
// and the path mention in `use` must NOT fire.
use std::collections::{BTreeMap, HashMap};

pub struct Pending {
    ops: HashMap<u64, Vec<f32>>,
    order: Vec<u64>,
    ranked: BTreeMap<u64, f32>,
}

pub fn keyed(p: &mut Pending, seq: u64) -> Option<Vec<f32>> {
    if p.ops.contains_key(&seq) {
        return p.ops.remove(&seq);
    }
    p.ops.insert(seq, Vec::new());
    None
}

pub fn ordered_emit(p: &Pending, out: &mut Vec<f32>) {
    // deterministic: Vec order and BTreeMap key order, never hash order
    for seq in &p.order {
        if let Some(part) = p.ops.get(seq) {
            out.extend_from_slice(part);
        }
    }
    for (_k, v) in &p.ranked {
        out.push(*v);
    }
}

pub fn vec_retain(p: &mut Pending) {
    p.order.retain(|&s| s != 0);
}
