// Fixture near-miss: documented unsafe (including through attributes) and
// the word unsafe inside comments/strings must NOT fire.

// the string below mentions unsafe { } but is not code
pub const DOC: &str = "never write unsafe { } without a reason";

// SAFETY: lengths are equal by the caller's contract, and the regions
// never overlap because dst is freshly allocated.
#[inline]
pub unsafe fn copy_exact(src: &[u16], dst: &mut [u16]) {
    std::ptr::copy_nonoverlapping(src.as_ptr(), dst.as_mut_ptr(), src.len());
}

pub fn trailing_form(p: *const u8) -> u8 {
    unsafe { *p } // SAFETY: caller passes a pointer to a live byte
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_use_undocumented_unsafe() {
        let x = 1u8;
        let y = unsafe { *(&x as *const u8) };
        assert_eq!(y, 1);
    }
}
