// Fixture near-miss: a consistent global acquisition order (state before
// tx in every fn) must NOT fire.
use std::sync::{Mutex, MutexGuard};

pub struct Shared {
    state: Mutex<Vec<u64>>,
    tx: Mutex<Vec<u8>>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

pub fn forward(sh: &Shared) {
    let s = lock(&sh.state);
    let mut t = lock(&sh.tx);
    t.extend_from_slice(&s.len().to_le_bytes());
}

pub fn progress_one(sh: &Shared) {
    let s = sh.state.lock().unwrap_or_else(|p| p.into_inner());
    let mut t = sh.tx.lock().unwrap_or_else(|p| p.into_inner());
    t.push(s.len() as u8);
}

pub fn state_only(sh: &Shared) -> usize {
    lock(&sh.state).len()
}
