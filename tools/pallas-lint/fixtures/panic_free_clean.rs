// Fixture near-miss: error propagation, unwrap_or-family fallbacks, the
// poison-recovery idiom, and test-only unwraps must NOT fire.
use std::sync::{Mutex, MutexGuard};

pub fn decode(b: &[u8]) -> Result<u32, String> {
    if b.len() < 4 {
        return Err("short buffer".to_string());
    }
    let mut arr = [0u8; 4];
    arr.copy_from_slice(&b[..4]);
    Ok(u32::from_le_bytes(arr))
}

pub fn first_or_zero(v: &[u32]) -> u32 {
    v.first().copied().unwrap_or(0)
}

// the word unwrap() in a comment and "panic!" in a string are not calls
pub const HINT: &str = "never panic! at a boundary";

pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        let v = vec![1u32];
        assert_eq!(*v.first().unwrap(), 1);
    }
}
