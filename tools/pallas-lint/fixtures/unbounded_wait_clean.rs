// Fixture near-miss: deadline-bounded waits must NOT fire — wait_timeout
// and wait_timeout_while against a configured budget, a finite read
// deadline, and a justified allow on the one intentionally unbounded
// reader read.
use std::net::TcpStream;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

pub struct Inbox {
    queue: Mutex<Vec<u8>>,
    cv: Condvar,
}

pub fn recv_one(ib: &Inbox, budget: Duration) -> Option<u8> {
    let mut q = match ib.queue.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    while q.is_empty() {
        let (g, res) = match ib.cv.wait_timeout(q, budget) {
            Ok(r) => r,
            Err(p) => p.into_inner(),
        };
        q = g;
        if res.timed_out() {
            return None;
        }
    }
    Some(q.remove(0))
}

pub fn recv_all(ib: &Inbox, budget: Duration) -> usize {
    let (q, _res) = match ib.cv.wait_timeout_while(
        match ib.queue.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        },
        budget,
        |q| q.is_empty(),
    ) {
        Ok(r) => r,
        Err(p) => p.into_inner(),
    };
    q.len()
}

pub fn arm_deadline(sock: &TcpStream, budget: Duration) -> std::io::Result<()> {
    sock.set_read_timeout(Some(budget))
}

pub fn reader_read(sock: &TcpStream) -> std::io::Result<()> {
    // lint: allow(unbounded-wait) — reader thread; shutdown() on poison unblocks this read
    sock.set_read_timeout(None)
}
