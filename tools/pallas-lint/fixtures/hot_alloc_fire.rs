// Fixture: allocation inside a manifest hot-path fn must fire.
pub fn gemm_rows(c: &mut [f32], a: &[f32], b: &[f32], k: usize) {
    let mut scratch = Vec::new();
    for (i, &av) in a.iter().enumerate() {
        scratch.push(av * b[i % k]);
    }
    let copied = scratch.to_vec();
    let label = format!("rows={}", copied.len());
    let _ = label.clone();
    for (ci, &s) in c.iter_mut().zip(&copied) {
        *ci += s;
    }
}
