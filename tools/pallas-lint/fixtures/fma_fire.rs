// Fixture: fused multiply-add in a kernel module must fire, in both the
// method and the intrinsic form.
pub fn axpy(acc: &mut [f32], a: f32, b: &[f32]) {
    for (c, &x) in acc.iter_mut().zip(b) {
        *c = x.mul_add(a, *c);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "fma")]
// SAFETY: caller checked the fma feature; bounds are the slice lengths.
pub unsafe fn axpy_fma(acc: &mut [f32], a: f32, b: &[f32]) {
    use std::arch::x86_64::*;
    let va = _mm256_set1_ps(a);
    let vb = _mm256_loadu_ps(b.as_ptr());
    let vc = _mm256_loadu_ps(acc.as_ptr());
    _mm256_storeu_ps(acc.as_mut_ptr(), _mm256_fmadd_ps(va, vb, vc));
}
