// Fixture: opposite acquisition orders across two fns must fire, through
// both the method form and the poison-recovery helper form.
use std::sync::{Mutex, MutexGuard};

pub struct Shared {
    state: Mutex<Vec<u64>>,
    tx: Mutex<Vec<u8>>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

pub fn forward(sh: &Shared) {
    let s = lock(&sh.state);
    let mut t = lock(&sh.tx);
    t.extend_from_slice(&s.len().to_le_bytes());
}

pub fn backward(sh: &Shared) {
    let mut t = lock(&sh.tx);
    let s = lock(&sh.state);
    t.extend_from_slice(&s.len().to_le_bytes());
}
