// Fixture near-miss: the same allocations in a NON-manifest fn, and a
// manifest fn that only reuses caller-provided buffers, must NOT fire.
pub fn gemm_rows(c: &mut [f32], a: &[f32], b: &[f32], k: usize) {
    for (i, &av) in a.iter().enumerate() {
        let row = &b[(i % k) * k..(i % k + 1) * k];
        for (ci, &bv) in c.iter_mut().zip(row) {
            *ci += av * bv;
        }
    }
}

pub fn gemm_rows_setup(k: usize) -> Vec<f32> {
    // setup paths may allocate: this fn is not in the manifest
    let mut ws = Vec::with_capacity(k);
    ws.resize(k, 0.0);
    ws.to_vec()
}
