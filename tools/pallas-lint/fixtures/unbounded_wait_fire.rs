// Fixture: deadline-free blocking waits must fire — the Condvar method
// form, the wait_while form, and clearing a socket read deadline.
use std::net::TcpStream;
use std::sync::{Condvar, Mutex};

pub struct Inbox {
    queue: Mutex<Vec<u8>>,
    cv: Condvar,
}

pub fn recv_one(ib: &Inbox) -> u8 {
    let mut q = match ib.queue.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    while q.is_empty() {
        q = match ib.cv.wait(q) {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
    }
    q.remove(0)
}

pub fn recv_all(ib: &Inbox) -> usize {
    let q = match ib.queue.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    let q = match ib.cv.wait_while(q, |q| q.is_empty()) {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    q.len()
}

pub fn clear_deadline(sock: &TcpStream) -> std::io::Result<()> {
    sock.set_read_timeout(None)
}
