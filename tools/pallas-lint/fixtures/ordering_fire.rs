// Fixture: iterating a HashMap/HashSet in an order-sensitive module must
// fire, in both the method and the for-loop form.
use std::collections::{HashMap, HashSet};

pub struct Pending {
    ops: HashMap<u64, Vec<f32>>,
}

pub fn drain_sums(p: &mut Pending, out: &mut Vec<f32>) {
    for (_seq, part) in p.ops.drain() {
        out.extend(part);
    }
}

pub fn emit(p: &Pending, out: &mut Vec<u64>) {
    for seq in &p.ops {
        out.push(*seq.0);
    }
}

pub fn tags(seen: HashSet<u64>) -> Vec<u64> {
    seen.iter().copied().collect()
}
