//! `pallas-lint` — repo-specific static analysis for the scalegnn crate.
//!
//! Every scale claim this repository makes rests on invariants that no
//! general-purpose tool checks: bitwise determinism across thread counts,
//! transports and SIMD levels; panic-free decode boundaries; `// SAFETY:`
//! documentation on every `unsafe`; zero-allocation hot paths; and a
//! cycle-free mutex acquisition order in the in-process collective engine.
//! This crate turns those disciplines from reviewer folklore into tier-1
//! test failures: a hand-rolled, dependency-free Rust lexer feeds a rule
//! engine that walks `rust/src/**` and reports structured diagnostics.
//!
//! ## Rules
//!
//! | rule id | invariant |
//! |---|---|
//! | `safety-comment` | every `unsafe` is preceded by a `// SAFETY:` comment |
//! | `panic-free-boundary` | no `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!`/`unimplemented!` in the declared boundary modules |
//! | `determinism-ordering` | no `HashMap`/`HashSet` *iteration* in modules whose output reaches a reduction, the wire, or a checkpoint |
//! | `determinism-fma` | no `mul_add` / FMA intrinsics in kernel modules (bitwise discipline wants separate mul + add) |
//! | `hot-path-alloc` | no allocating calls inside the checked-in hot-path function manifest |
//! | `lock-order` | the per-crate mutex acquisition graph of the lock-scope modules is acyclic |
//! | `unbounded-wait` | no deadline-free blocking wait (`Condvar::wait`/`wait_while`, `set_read_timeout(None)`) in the distributed-runtime modules |
//!
//! ## Escapes
//!
//! A violation is silenced by an explicit, justified allow on the
//! preceding line (or at the end of the same line):
//!
//! ```text
//! // lint: allow(panic-free-boundary) — every slot is Some: completeness was checked under the lock
//! ```
//!
//! The justification is mandatory (an allow without one is itself a
//! `bad-allow` diagnostic and silences nothing) and every allow is
//! surfaced in the `--json` report so escapes stay auditable.
//!
//! The lexer understands line/block (nested) comments, string/char/raw
//! string/byte string literals, lifetimes and attributes, and records
//! `file:line` spans.  `#[cfg(test)]` / `#[test]` items are skipped —
//! test code may unwrap and allocate freely.  It is a *lexer*, not a
//! parser: rules are token-pattern based, kept honest by fixture tests
//! (`fixtures/` holds a firing snippet and a near-miss per rule).

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// Rule identifiers, in reporting order.  `bad-allow` is the engine's own
/// rule for malformed escape comments and cannot be disabled or allowed.
pub const RULE_IDS: [&str; 8] = [
    "safety-comment",
    "panic-free-boundary",
    "determinism-ordering",
    "determinism-fma",
    "hot-path-alloc",
    "lock-order",
    "unbounded-wait",
    "bad-allow",
];

/// One reported violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the linted root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    /// Rule id (one of [`RULE_IDS`]).
    pub rule: &'static str,
    /// Human-readable description of the violation.
    pub message: String,
}

impl Diagnostic {
    /// `file:line rule-id: message` (the text output format).
    pub fn render(&self) -> String {
        format!("{}:{} {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// One `// lint: allow(rule) — justification` escape found in the tree.
/// Surfaced in the JSON report whether or not it suppressed anything.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    /// Path relative to the linted root.
    pub file: String,
    /// 1-based line of the allow comment.
    pub line: u32,
    /// Rule the escape names.
    pub rule: String,
    /// The mandatory justification text.
    pub justification: String,
    /// Whether the allow actually suppressed a diagnostic.
    pub used: bool,
}

/// Result of a lint run: surviving diagnostics plus every escape.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Violations that were not suppressed, sorted by (file, line, rule).
    pub diagnostics: Vec<Diagnostic>,
    /// Every justified allow in the tree, sorted by (file, line).
    pub allows: Vec<Allow>,
}

impl Report {
    /// One line per diagnostic in `file:line rule-id: message` form.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.render());
            out.push('\n');
        }
        out
    }

    /// Stable machine-readable form (shape pinned by a fixture test):
    /// `{"version":1,"diagnostics":[{file,line,rule,message}...],`
    /// `"allows":[{file,line,rule,justification,used}...]}`.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\"version\":1,\"diagnostics\":[");
        for (i, d) in self.diagnostics.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"file\":{},\"line\":{},\"rule\":{},\"message\":{}}}",
                json_str(&d.file),
                d.line,
                json_str(d.rule),
                json_str(&d.message)
            ));
        }
        s.push_str("],\"allows\":[");
        for (i, a) in self.allows.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"file\":{},\"line\":{},\"rule\":{},\"justification\":{},\"used\":{}}}",
                json_str(&a.file),
                a.line,
                json_str(&a.rule),
                json_str(&a.justification),
                a.used
            ));
        }
        s.push_str("]}");
        s
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Scope and manifest configuration of a lint run.  [`Config::repo`] is
/// the checked-in configuration the tier-1 test enforces; fixture tests
/// build narrower ones.
#[derive(Debug, Clone)]
pub struct Config {
    /// Enabled rule ids (`bad-allow` is implicitly always on).
    pub enabled: Vec<String>,
    /// Panic-free modules: path prefixes relative to the linted root.
    pub boundary_modules: Vec<String>,
    /// Modules whose output reaches a reduction, the wire, or a
    /// checkpoint: map iteration order must not be observable.
    pub ordered_modules: Vec<String>,
    /// Kernel modules where FMA would break bitwise identity.
    pub fma_modules: Vec<String>,
    /// Modules participating in the mutex acquisition graph.
    pub lock_modules: Vec<String>,
    /// Distributed-runtime modules where every blocking wait must carry a
    /// deadline (the chaos/no-hang discipline of the fault-tolerance PR).
    pub wait_modules: Vec<String>,
    /// Hot-path manifest: `(path prefix, fn name)`; an empty prefix
    /// matches any file.
    pub hot_fns: Vec<(String, String)>,
}

impl Config {
    /// The repository configuration: boundary modules from PR 7/2/6, the
    /// kernel discipline of PR 1/8, and the hot-path manifest of PR 1/5.
    pub fn repo() -> Config {
        let s = |v: &[&str]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>();
        Config {
            enabled: RULE_IDS.iter().map(|r| r.to_string()).collect(),
            boundary_modules: s(&[
                "comm/wire.rs",
                "comm/socket.rs",
                "comm/coord.rs",
                "graph/store.rs",
                "checkpoint/",
            ]),
            ordered_modules: s(&["comm/", "checkpoint/", "graph/store.rs"]),
            fma_modules: s(&["tensor/", "pmm/", "model/"]),
            lock_modules: s(&["comm/inproc.rs", "comm/coord.rs"]),
            wait_modules: s(&["comm/socket.rs", "comm/coord.rs"]),
            hot_fns: vec![
                (String::new(), "train_step_ws".into()),
                (String::new(), "induce_rescaled_into".into()),
                (String::new(), "induce_rescaled_into_threads".into()),
                (String::new(), "sample_and_induce_into".into()),
                (String::new(), "make_into".into()),
                (String::new(), "gemm_rows".into()),
                (String::new(), "spmm_into".into()),
                (String::new(), "spmm_into_threads".into()),
                ("comm/".into(), "progress".into()),
                ("pmm/".into(), "progress".into()),
            ],
        }
    }

    /// Copy of this configuration with `rule` switched off (fixture tests
    /// prove each rule is live by disabling it and expecting silence).
    pub fn disable(mut self, rule: &str) -> Config {
        self.enabled.retain(|r| r != rule);
        self
    }

    fn on(&self, rule: &str) -> bool {
        self.enabled.iter().any(|r| r == rule)
    }
}

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Punct(char),
    /// String / char / byte / raw-string / lifetime / number literal —
    /// rules only need to know "not an identifier, not punctuation".
    Lit,
}

#[derive(Debug, Clone)]
struct Token {
    tok: Tok,
    line: u32,
}

fn skip_string(chars: &[char], mut i: usize, line: &mut u32) -> usize {
    // chars[i] is the opening '"'
    i += 1;
    while i < chars.len() {
        match chars[i] {
            '\\' => i += 2,
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

fn skip_raw_string(chars: &[char], mut i: usize, hashes: usize, line: &mut u32) -> usize {
    // chars[i] is the opening '"'
    i += 1;
    while i < chars.len() {
        if chars[i] == '\n' {
            *line += 1;
            i += 1;
            continue;
        }
        if chars[i] == '"' {
            let mut k = 0;
            while k < hashes && i + 1 + k < chars.len() && chars[i + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return i + 1 + hashes;
            }
        }
        i += 1;
    }
    i
}

fn skip_char_lit(chars: &[char], mut i: usize, line: &mut u32) -> usize {
    // chars[i] is the opening '\''
    i += 1;
    while i < chars.len() {
        match chars[i] {
            '\\' => i += 2,
            '\'' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

fn lex(src: &str) -> Vec<Token> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (incl. /// and //!)
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            continue;
        }
        // block comment, nested per Rust
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte string and byte char prefixes: r"", r#""#, b"", br"", b''
        if c == 'r' || c == 'b' {
            let (mut j, raw) = if c == 'b' && i + 1 < n && chars[i + 1] == 'r' {
                (i + 2, true)
            } else if c == 'r' {
                (i + 1, true)
            } else {
                (i + 1, false)
            };
            if raw {
                let mut hashes = 0usize;
                while j < n && chars[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && chars[j] == '"' {
                    let start = line;
                    i = skip_raw_string(&chars, j, hashes, &mut line);
                    toks.push(Token { tok: Tok::Lit, line: start });
                    continue;
                }
            } else if j < n && (chars[j] == '"' || chars[j] == '\'') {
                let start = line;
                i = if chars[j] == '"' {
                    skip_string(&chars, j, &mut line)
                } else {
                    skip_char_lit(&chars, j, &mut line)
                };
                toks.push(Token { tok: Tok::Lit, line: start });
                continue;
            }
            // plain identifier starting with r/b: fall through
        }
        if c == '"' {
            let start = line;
            i = skip_string(&chars, i, &mut line);
            toks.push(Token { tok: Tok::Lit, line: start });
            continue;
        }
        if c == '\'' {
            // char literal vs lifetime: 'x' / '\n' are literals, 'a in
            // generics is a lifetime (no closing quote after one char)
            if i + 1 < n && chars[i + 1] == '\\' {
                let start = line;
                i = skip_char_lit(&chars, i, &mut line);
                toks.push(Token { tok: Tok::Lit, line: start });
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                toks.push(Token { tok: Tok::Lit, line });
                i += 3;
                continue;
            }
            let mut j = i + 1;
            while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            toks.push(Token { tok: Tok::Lit, line });
            i = j.max(i + 1);
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n {
                let d = chars[j];
                if d.is_ascii_alphanumeric() || d == '_' {
                    j += 1;
                    continue;
                }
                // decimal point only when a digit follows (so `0..n`
                // keeps its range dots as punctuation)
                if d == '.' && j + 1 < n && chars[j + 1].is_ascii_digit() {
                    j += 2;
                    continue;
                }
                break;
            }
            toks.push(Token { tok: Tok::Lit, line });
            i = j;
            continue;
        }
        if c.is_alphanumeric() || c == '_' {
            let mut j = i;
            let mut s = String::new();
            while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                s.push(chars[j]);
                j += 1;
            }
            toks.push(Token { tok: Tok::Ident(s), line });
            i = j;
            continue;
        }
        toks.push(Token { tok: Tok::Punct(c), line });
        i += 1;
    }
    toks
}

// ---------------------------------------------------------------------------
// Item segmentation: #[cfg(test)] spans and fn bodies
// ---------------------------------------------------------------------------

/// Scan an attribute starting at `i` (`toks[i]` is `#`).  Returns the
/// index just past the closing `]` and whether the attribute marks test
/// code (`#[test]`, `#[cfg(test)]`, `#[cfg(any(test, ...))]`, `#[bench]`
/// — but not `#[cfg(not(test))]`, and never inner `#![...]` attributes).
fn scan_attr(toks: &[Token], i: usize) -> (usize, bool) {
    let mut j = i + 1;
    let inner = matches!(toks.get(j).map(|t| &t.tok), Some(Tok::Punct('!')));
    if inner {
        j += 1;
    }
    if !matches!(toks.get(j).map(|t| &t.tok), Some(Tok::Punct('['))) {
        return (i + 1, false);
    }
    let mut depth = 0usize;
    let mut is_test = false;
    while j < toks.len() {
        match &toks[j].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => {
                depth -= 1;
                if depth == 0 {
                    return (j + 1, is_test && !inner);
                }
            }
            Tok::Ident(s) if s == "test" || s == "bench" => {
                let negated = j >= 2
                    && matches!(&toks[j - 1].tok, Tok::Punct('('))
                    && matches!(&toks[j - 2].tok, Tok::Ident(x) if x == "not");
                if !negated {
                    is_test = true;
                }
            }
            _ => {}
        }
        j += 1;
    }
    (j, false)
}

/// From `j` (just past an item's attributes) return the index just past
/// the item: through the matching `}` of its first top-level brace, or
/// just past a terminating `;`.
fn scan_item(toks: &[Token], mut j: usize) -> usize {
    while j < toks.len() {
        match &toks[j].tok {
            Tok::Punct(';') => return j + 1,
            Tok::Punct('{') => {
                let mut depth = 0usize;
                while j < toks.len() {
                    match &toks[j].tok {
                        Tok::Punct('{') => depth += 1,
                        Tok::Punct('}') => {
                            depth -= 1;
                            if depth == 0 {
                                return j + 1;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                return j;
            }
            _ => j += 1,
        }
    }
    j
}

fn find_test_spans(toks: &[Token]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if matches!(toks[i].tok, Tok::Punct('#')) {
            let start = i;
            let (mut end, is_test) = scan_attr(toks, i);
            if is_test {
                // consume any further attributes of the same item
                while matches!(toks.get(end).map(|t| &t.tok), Some(Tok::Punct('#'))) {
                    end = scan_attr(toks, end).0;
                }
                let item_end = scan_item(toks, end);
                spans.push((start, item_end));
                i = item_end;
                continue;
            }
            i = end;
            continue;
        }
        i += 1;
    }
    spans
}

#[derive(Debug, Clone)]
struct FnInfo {
    name: String,
    /// Token index range of the body including its braces.
    body: (usize, usize),
}

fn find_fns(toks: &[Token]) -> Vec<FnInfo> {
    let mut fns = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if matches!(&toks[i].tok, Tok::Ident(s) if s == "fn") {
            if let Some(Tok::Ident(name)) = toks.get(i + 1).map(|t| &t.tok) {
                let mut j = i + 2;
                while j < toks.len() {
                    match &toks[j].tok {
                        // trait method declaration: no body to scan
                        Tok::Punct(';') => break,
                        Tok::Punct('{') => {
                            let start = j;
                            let mut depth = 0usize;
                            while j < toks.len() {
                                match &toks[j].tok {
                                    Tok::Punct('{') => depth += 1,
                                    Tok::Punct('}') => {
                                        depth -= 1;
                                        if depth == 0 {
                                            break;
                                        }
                                    }
                                    _ => {}
                                }
                                j += 1;
                            }
                            fns.push(FnInfo {
                                name: name.clone(),
                                body: (start, (j + 1).min(toks.len())),
                            });
                            break;
                        }
                        _ => j += 1,
                    }
                }
            }
        }
        i += 1;
    }
    fns
}

// ---------------------------------------------------------------------------
// Per-file analysis context
// ---------------------------------------------------------------------------

struct AllowRec {
    line: u32,
    rule: String,
    justification: String,
    used: bool,
}

struct FileCtx {
    path: String,
    toks: Vec<Token>,
    test_spans: Vec<(usize, usize)>,
    fns: Vec<FnInfo>,
    lines: Vec<String>,
    allows: Vec<AllowRec>,
}

impl FileCtx {
    fn new(path: &str, src: &str, diags: &mut Vec<Diagnostic>) -> FileCtx {
        let toks = lex(src);
        let test_spans = find_test_spans(&toks);
        let fns = find_fns(&toks);
        let lines: Vec<String> = src.lines().map(|l| l.to_string()).collect();
        let allows = parse_allows(path, &lines, diags);
        FileCtx { path: path.to_string(), toks, test_spans, fns, lines, allows }
    }

    fn in_test(&self, idx: usize) -> bool {
        self.test_spans.iter().any(|&(s, e)| idx >= s && idx < e)
    }

    /// True when `line` (1-based) carries or is preceded by a `// SAFETY:`
    /// comment block; intervening attribute lines are skipped.
    fn has_safety_comment(&self, line: u32) -> bool {
        let idx = line as usize - 1;
        if let Some(raw) = self.lines.get(idx) {
            if let Some(p) = raw.find("//") {
                if raw[p..].contains("SAFETY:") {
                    return true;
                }
            }
        }
        let mut k = idx;
        while k > 0 {
            k -= 1;
            let t = self.lines[k].trim();
            if t.starts_with("//") {
                if t.contains("SAFETY:") {
                    return true;
                }
                continue;
            }
            if t.starts_with("#[") || t.starts_with("#!") {
                continue;
            }
            return false;
        }
        false
    }
}

fn parse_allows(path: &str, lines: &[String], diags: &mut Vec<Diagnostic>) -> Vec<AllowRec> {
    let mut out = Vec::new();
    for (idx, raw) in lines.iter().enumerate() {
        let line = idx as u32 + 1;
        let Some(cpos) = raw.find("//") else { continue };
        let c = &raw[cpos..];
        let Some(apos) = c.find("lint: allow(").or_else(|| c.find("lint:allow(")) else {
            continue;
        };
        let after = &c[apos..];
        let Some(open) = after.find('(') else { continue };
        let Some(close) = after.find(')') else {
            diags.push(Diagnostic {
                file: path.to_string(),
                line,
                rule: "bad-allow",
                message: "unterminated lint: allow(...)".to_string(),
            });
            continue;
        };
        if close < open {
            continue;
        }
        let rule = after[open + 1..close].trim().to_string();
        let known = RULE_IDS.iter().any(|r| *r == rule && *r != "bad-allow");
        if !known {
            diags.push(Diagnostic {
                file: path.to_string(),
                line,
                rule: "bad-allow",
                message: format!("allow names unknown rule '{rule}'"),
            });
            continue;
        }
        let justification = after[close + 1..]
            .trim_start_matches(|ch: char| {
                ch.is_whitespace() || ch == '—' || ch == '-' || ch == ':' || ch == '·'
            })
            .trim()
            .to_string();
        if justification.is_empty() {
            diags.push(Diagnostic {
                file: path.to_string(),
                line,
                rule: "bad-allow",
                message: format!(
                    "allow({rule}) needs a justification: `// lint: allow({rule}) — why`"
                ),
            });
            continue;
        }
        out.push(AllowRec { line, rule, justification, used: false });
    }
    out
}

fn in_scope(path: &str, modules: &[String]) -> bool {
    modules.iter().any(|m| path.starts_with(m.as_str()))
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

fn ident_at<'a>(toks: &'a [Token], i: usize) -> Option<&'a str> {
    match toks.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

fn punct_at(toks: &[Token], i: usize, c: char) -> bool {
    matches!(toks.get(i).map(|t| &t.tok), Some(Tok::Punct(p)) if *p == c)
}

fn check_safety(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    for (i, t) in ctx.toks.iter().enumerate() {
        if !matches!(&t.tok, Tok::Ident(s) if s == "unsafe") || ctx.in_test(i) {
            continue;
        }
        if ctx.has_safety_comment(t.line) {
            continue;
        }
        diags.push(Diagnostic {
            file: ctx.path.clone(),
            line: t.line,
            rule: "safety-comment",
            message: "`unsafe` without a preceding `// SAFETY:` comment documenting \
                      the precondition that makes it sound"
                .to_string(),
        });
    }
}

fn check_panic_free(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    let toks = &ctx.toks;
    for i in 0..toks.len() {
        if ctx.in_test(i) {
            continue;
        }
        if let Some(name) = ident_at(toks, i) {
            let method = (name == "unwrap" || name == "expect") && i > 0 && punct_at(toks, i - 1, '.');
            let macro_call = matches!(name, "panic" | "unreachable" | "todo" | "unimplemented")
                && punct_at(toks, i + 1, '!');
            if method {
                diags.push(Diagnostic {
                    file: ctx.path.clone(),
                    line: toks[i].line,
                    rule: "panic-free-boundary",
                    message: format!(
                        "`.{name}()` in a panic-free boundary module — decode and I/O \
                         failures here must stay descriptive errors, never panics"
                    ),
                });
            } else if macro_call {
                diags.push(Diagnostic {
                    file: ctx.path.clone(),
                    line: toks[i].line,
                    rule: "panic-free-boundary",
                    message: format!(
                        "`{name}!` in a panic-free boundary module — return a \
                         descriptive error instead"
                    ),
                });
            }
        }
    }
}

const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "into_keys",
    "values",
    "values_mut",
    "into_values",
    "drain",
    "retain",
];

fn check_ordering(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    let toks = &ctx.toks;
    // names declared or initialized as HashMap / HashSet in this file
    let mut names: BTreeSet<String> = BTreeSet::new();
    for i in 0..toks.len() {
        let Some(ty) = ident_at(toks, i) else { continue };
        if ty != "HashMap" && ty != "HashSet" {
            continue;
        }
        // path-qualified mention (`std::collections::HashMap`) is not a decl
        if i >= 2 && punct_at(toks, i - 1, ':') && punct_at(toks, i - 2, ':') {
            continue;
        }
        // walk back over `&` and `mut` to `name :` or `name =`
        let mut k = i;
        while k > 0 {
            let prev = k - 1;
            if punct_at(toks, prev, '&') || ident_at(toks, prev) == Some("mut") {
                k = prev;
                continue;
            }
            break;
        }
        if k == 0 {
            continue;
        }
        let sep = k - 1;
        let is_decl = punct_at(toks, sep, ':') || punct_at(toks, sep, '=');
        if !is_decl || sep == 0 {
            continue;
        }
        // a `::` before the separator means a path, not a binding
        if punct_at(toks, sep, ':') && sep >= 1 && punct_at(toks, sep - 1, ':') {
            continue;
        }
        if let Some(name) = ident_at(toks, sep - 1) {
            names.insert(name.to_string());
        }
    }
    if names.is_empty() {
        return;
    }
    // receiver.iter_method(...)
    for i in 0..toks.len() {
        if ctx.in_test(i) {
            continue;
        }
        if let Some(m) = ident_at(toks, i) {
            if ITER_METHODS.contains(&m) && i >= 2 && punct_at(toks, i - 1, '.') {
                if let Some(recv) = ident_at(toks, i - 2) {
                    if names.contains(recv) {
                        diags.push(Diagnostic {
                            file: ctx.path.clone(),
                            line: toks[i].line,
                            rule: "determinism-ordering",
                            message: format!(
                                "`{recv}.{m}()` iterates a HashMap/HashSet in an \
                                 order-sensitive module — arrival at a reduction, the \
                                 wire, or a checkpoint must not depend on hash order \
                                 (use BTreeMap or an indexed loop)"
                            ),
                        });
                    }
                }
            }
        }
    }
    // for ... in [&][mut] name
    let mut i = 0usize;
    while i < toks.len() {
        if ident_at(toks, i) == Some("for") && !ctx.in_test(i) {
            let mut j = i + 1;
            let limit = (i + 40).min(toks.len());
            while j < limit {
                if punct_at(toks, j, '{') || punct_at(toks, j, ';') {
                    break;
                }
                if ident_at(toks, j) == Some("in") {
                    let mut k = j + 1;
                    while punct_at(toks, k, '&')
                        || punct_at(toks, k, '(')
                        || ident_at(toks, k) == Some("mut")
                    {
                        k += 1;
                    }
                    // walk a dotted path (`sh.state.ops`) to its last
                    // segment; a trailing `(` means a method call, which
                    // the receiver scan above already covers
                    while ident_at(toks, k).is_some()
                        && punct_at(toks, k + 1, '.')
                        && ident_at(toks, k + 2).is_some()
                    {
                        k += 2;
                    }
                    if let Some(name) = ident_at(toks, k) {
                        if names.contains(name) && !punct_at(toks, k + 1, '(') {
                            diags.push(Diagnostic {
                                file: ctx.path.clone(),
                                line: toks[k].line,
                                rule: "determinism-ordering",
                                message: format!(
                                    "`for ... in {name}` iterates a HashMap/HashSet in an \
                                     order-sensitive module — hash order must not reach a \
                                     reduction, the wire, or a checkpoint"
                                ),
                            });
                        }
                    }
                    break;
                }
                j += 1;
            }
        }
        i += 1;
    }
}

fn check_fma(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    let toks = &ctx.toks;
    for i in 0..toks.len() {
        if ctx.in_test(i) {
            continue;
        }
        let Some(name) = ident_at(toks, i) else { continue };
        let is_fma = (name == "mul_add" && i > 0 && punct_at(toks, i - 1, '.'))
            || (name.starts_with("_mm") && name.contains("fmadd"))
            || name.starts_with("vfma");
        if is_fma {
            diags.push(Diagnostic {
                file: ctx.path.clone(),
                line: toks[i].line,
                rule: "determinism-fma",
                message: format!(
                    "`{name}` fuses multiply and add — the bitwise kernel discipline \
                     requires separate mul + add so SIMD and scalar paths round \
                     identically"
                ),
            });
        }
    }
}

fn check_hot_alloc(ctx: &FileCtx, cfg: &Config, diags: &mut Vec<Diagnostic>) {
    let manifest: Vec<&str> = cfg
        .hot_fns
        .iter()
        .filter(|(prefix, _)| prefix.is_empty() || ctx.path.starts_with(prefix.as_str()))
        .map(|(_, name)| name.as_str())
        .collect();
    if manifest.is_empty() {
        return;
    }
    let toks = &ctx.toks;
    for f in &ctx.fns {
        if !manifest.contains(&f.name.as_str()) || ctx.in_test(f.body.0) {
            continue;
        }
        for i in f.body.0..f.body.1.min(toks.len()) {
            let Some(name) = ident_at(toks, i) else { continue };
            let path_call = |head: &str, tails: &[&str]| {
                name == head
                    && punct_at(toks, i + 1, ':')
                    && punct_at(toks, i + 2, ':')
                    && ident_at(toks, i + 3).map_or(false, |t| tails.contains(&t))
            };
            let offending: Option<String> = if path_call("Vec", &["new", "with_capacity"]) {
                Some(format!("Vec::{}", ident_at(toks, i + 3).unwrap_or("new")))
            } else if path_call("Box", &["new"]) {
                Some("Box::new".to_string())
            } else if path_call("String", &["from", "new"]) {
                Some(format!("String::{}", ident_at(toks, i + 3).unwrap_or("from")))
            } else if (name == "vec" || name == "format") && punct_at(toks, i + 1, '!') {
                Some(format!("{name}!"))
            } else if matches!(name, "to_vec" | "collect" | "clone" | "to_string" | "to_owned")
                && i > 0
                && punct_at(toks, i - 1, '.')
            {
                Some(format!(".{name}()"))
            } else {
                None
            };
            if let Some(what) = offending {
                diags.push(Diagnostic {
                    file: ctx.path.clone(),
                    line: toks[i].line,
                    rule: "hot-path-alloc",
                    message: format!(
                        "`{what}` inside hot-path fn `{}` — the zero-allocation \
                         manifest requires reused workspace buffers here",
                        f.name
                    ),
                });
            }
        }
    }
}

fn check_unbounded_wait(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    let toks = &ctx.toks;
    for i in 0..toks.len() {
        if ctx.in_test(i) {
            continue;
        }
        let Some(name) = ident_at(toks, i) else { continue };
        // Condvar::wait / wait_while method calls; the deadline-carrying
        // wait_timeout / wait_timeout_while idents are distinct, so they
        // never match.
        if (name == "wait" || name == "wait_while")
            && i > 0
            && punct_at(toks, i - 1, '.')
            && punct_at(toks, i + 1, '(')
        {
            diags.push(Diagnostic {
                file: ctx.path.clone(),
                line: toks[i].line,
                rule: "unbounded-wait",
                message: format!(
                    "`.{name}()` blocks with no deadline — distributed-runtime waits \
                     must use `wait_timeout` against the configured `wait_timeout_ms` \
                     so a stalled peer becomes a `Stalled` failure origin, not a hang"
                ),
            });
        }
        // clearing a socket read deadline re-opens the hang window
        if name == "set_read_timeout"
            && punct_at(toks, i + 1, '(')
            && ident_at(toks, i + 2) == Some("None")
        {
            diags.push(Diagnostic {
                file: ctx.path.clone(),
                line: toks[i].line,
                rule: "unbounded-wait",
                message: "`set_read_timeout(None)` makes reads block forever — keep a \
                          finite deadline so a dead peer surfaces as a structured \
                          failure origin instead of a hang"
                    .to_string(),
            });
        }
    }
}

/// One mutex acquisition: receiver/guard name plus its witness location.
struct LockAcq {
    name: String,
    file: String,
    line: u32,
    func: String,
}

/// Collect `name.lock()` / `name.try_lock()` plus the repo's sanctioned
/// poison-recovering helpers `lock(&...name)` / `lock_unpoisoned(&...name)`
/// into per-function acquisition sequences.
fn collect_locks(ctx: &FileCtx, out: &mut Vec<Vec<LockAcq>>) {
    let toks = &ctx.toks;
    for f in &ctx.fns {
        if ctx.in_test(f.body.0) {
            continue;
        }
        let mut seq: Vec<LockAcq> = Vec::new();
        let mut i = f.body.0;
        while i < f.body.1.min(toks.len()) {
            if let Some(name) = ident_at(toks, i) {
                // receiver.lock() / receiver.try_lock()
                if (name == "lock" || name == "try_lock")
                    && i >= 2
                    && punct_at(toks, i - 1, '.')
                    && punct_at(toks, i + 1, '(')
                {
                    if let Some(recv) = ident_at(toks, i - 2) {
                        seq.push(LockAcq {
                            name: recv.to_string(),
                            file: ctx.path.clone(),
                            line: toks[i].line,
                            func: f.name.clone(),
                        });
                    }
                } else if (name == "lock" || name == "lock_unpoisoned")
                    && punct_at(toks, i + 1, '(')
                    && !(i >= 1 && punct_at(toks, i - 1, '.'))
                {
                    // helper call: the guarded mutex is the last ident of
                    // the receiver path before any indexing
                    let mut j = i + 2;
                    let mut depth = 1usize;
                    let mut last: Option<&str> = None;
                    while j < toks.len() && depth > 0 {
                        match &toks[j].tok {
                            Tok::Punct('(') => depth += 1,
                            Tok::Punct(')') => depth -= 1,
                            Tok::Punct('[') if depth == 1 => break,
                            Tok::Ident(s) if depth == 1 => last = Some(s.as_str()),
                            _ => {}
                        }
                        j += 1;
                    }
                    if let Some(recv) = last {
                        seq.push(LockAcq {
                            name: recv.to_string(),
                            file: ctx.path.clone(),
                            line: toks[i].line,
                            func: f.name.clone(),
                        });
                    }
                }
            }
            i += 1;
        }
        if !seq.is_empty() {
            out.push(seq);
        }
    }
}

/// Build the acquisition graph (edge `a -> b` when `b` is acquired after
/// `a` within one function body) and report every strongly-connected
/// component with more than one lock name as an ordering cycle.
fn check_lock_cycles(seqs: &[Vec<LockAcq>], diags: &mut Vec<Diagnostic>) {
    let mut edges: BTreeMap<(String, String), (String, u32, String)> = BTreeMap::new();
    let mut nodes: BTreeSet<String> = BTreeSet::new();
    for seq in seqs {
        for a in seq.iter() {
            nodes.insert(a.name.clone());
        }
        for (ai, a) in seq.iter().enumerate() {
            for b in seq.iter().skip(ai + 1) {
                if a.name != b.name {
                    edges
                        .entry((a.name.clone(), b.name.clone()))
                        .or_insert((b.file.clone(), b.line, b.func.clone()));
                }
            }
        }
    }
    let names: Vec<&String> = nodes.iter().collect();
    let n = names.len();
    let idx_of = |s: &str| names.iter().position(|x| x.as_str() == s);
    // reachability closure
    let mut reach = vec![vec![false; n]; n];
    for (a, b) in edges.keys() {
        if let (Some(i), Some(j)) = (idx_of(a), idx_of(b)) {
            reach[i][j] = true;
        }
    }
    for k in 0..n {
        for i in 0..n {
            if reach[i][k] {
                for j in 0..n {
                    if reach[k][j] {
                        reach[i][j] = true;
                    }
                }
            }
        }
    }
    // SCCs by mutual reachability; deterministic by sorted name order
    let mut assigned = vec![false; n];
    for i in 0..n {
        if assigned[i] {
            continue;
        }
        let mut comp = vec![i];
        for j in (i + 1)..n {
            if !assigned[j] && reach[i][j] && reach[j][i] {
                comp.push(j);
            }
        }
        if comp.len() > 1 {
            for &c in &comp {
                assigned[c] = true;
            }
            let members: Vec<&str> = comp.iter().map(|&c| names[c].as_str()).collect();
            // witness: smallest (file, line) among the component's edges
            let mut witness: Option<(String, u32, String, String, String)> = None;
            for ((a, b), (file, line, func)) in &edges {
                if members.contains(&a.as_str()) && members.contains(&b.as_str()) {
                    let cand = (file.clone(), *line, func.clone(), a.clone(), b.clone());
                    let better = match &witness {
                        None => true,
                        Some(w) => (&cand.0, cand.1) < (&w.0, w.1),
                    };
                    if better {
                        witness = Some(cand);
                    }
                }
            }
            if let Some((file, line, func, a, b)) = witness {
                diags.push(Diagnostic {
                    file,
                    line,
                    rule: "lock-order",
                    message: format!(
                        "mutex acquisition cycle among {{{}}} — fn `{func}` takes \
                         `{b}` after `{a}` while another path takes them in the \
                         opposite order; pick one global order",
                        members.join(", ")
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// Lint in-memory sources.  `files` holds `(path, source)` pairs where
/// `path` is relative to the conceptual source root (`comm/wire.rs`,
/// `tensor/simd.rs`, ...) — scope matching is prefix-based on it.
pub fn lint_sources(files: &[(String, String)], cfg: &Config) -> Report {
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut allows: Vec<Allow> = Vec::new();
    let mut lock_seqs: Vec<Vec<LockAcq>> = Vec::new();
    let mut ctxs: Vec<FileCtx> = Vec::new();
    for (path, src) in files {
        let ctx = FileCtx::new(path, src, &mut diags);
        if cfg.on("safety-comment") {
            check_safety(&ctx, &mut diags);
        }
        if cfg.on("panic-free-boundary") && in_scope(path, &cfg.boundary_modules) {
            check_panic_free(&ctx, &mut diags);
        }
        if cfg.on("determinism-ordering") && in_scope(path, &cfg.ordered_modules) {
            check_ordering(&ctx, &mut diags);
        }
        if cfg.on("determinism-fma") && in_scope(path, &cfg.fma_modules) {
            check_fma(&ctx, &mut diags);
        }
        if cfg.on("hot-path-alloc") {
            check_hot_alloc(&ctx, cfg, &mut diags);
        }
        if cfg.on("lock-order") && in_scope(path, &cfg.lock_modules) {
            collect_locks(&ctx, &mut lock_seqs);
        }
        if cfg.on("unbounded-wait") && in_scope(path, &cfg.wait_modules) {
            check_unbounded_wait(&ctx, &mut diags);
        }
        ctxs.push(ctx);
    }
    if cfg.on("lock-order") {
        check_lock_cycles(&lock_seqs, &mut diags);
    }
    // apply allows: an allow on line L suppresses a same-rule diagnostic
    // on L (trailing form) or L+1 (preceding-line form)
    for ctx in &mut ctxs {
        for a in &mut ctx.allows {
            let before = diags.len();
            diags.retain(|d| {
                !(d.file == ctx.path
                    && d.rule == a.rule
                    && d.rule != "bad-allow"
                    && (d.line == a.line || d.line == a.line + 1))
            });
            if diags.len() < before {
                a.used = true;
            }
        }
        for a in &ctx.allows {
            allows.push(Allow {
                file: ctx.path.clone(),
                line: a.line,
                rule: a.rule.clone(),
                justification: a.justification.clone(),
                used: a.used,
            });
        }
    }
    let rule_rank =
        |r: &str| RULE_IDS.iter().position(|x| *x == r).unwrap_or(RULE_IDS.len());
    diags.sort_by(|a, b| {
        (&a.file, a.line, rule_rank(a.rule), &a.message)
            .cmp(&(&b.file, b.line, rule_rank(b.rule), &b.message))
    });
    diags.dedup();
    allows.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Report { diagnostics: diags, allows }
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir)
        .map_err(|e| format!("reading directory {}: {e}", dir.display()))?;
    let mut entries: Vec<std::path::PathBuf> =
        rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(root, &p, out)?;
        } else if p.extension().map_or(false, |x| x == "rs") {
            let rel = p
                .strip_prefix(root)
                .map_err(|e| format!("path {} outside root: {e}", p.display()))?;
            let rel = rel
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (normally `rust/src`), in sorted
/// path order so reports are deterministic.
pub fn lint_tree(root: &Path, cfg: &Config) -> Result<Report, String> {
    let mut rels = Vec::new();
    collect_rs(root, root, &mut rels)?;
    rels.sort();
    let mut files = Vec::with_capacity(rels.len());
    for rel in rels {
        let full = root.join(&rel);
        let src = std::fs::read_to_string(&full)
            .map_err(|e| format!("reading {}: {e}", full.display()))?;
        files.push((rel, src));
    }
    Ok(lint_sources(&files, cfg))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_one(path: &str, src: &str) -> Report {
        lint_sources(&[(path.to_string(), src.to_string())], &Config::repo())
    }

    #[test]
    fn lexer_survives_strings_comments_and_lifetimes() {
        let src = r##"
// a comment with unsafe and .unwrap() inside
/* block /* nested */ still comment .unwrap() */
fn f<'a>(x: &'a str) -> char {
    let _s = "string with // not a comment and \" escape";
    let _r = r#"raw "string" with .unwrap()"#;
    let _b = b"bytes";
    let _c = 'x';
    let _e = '\n';
    let _n = 0x7fff_ffff + 1e-30 + 0.5;
    'x'
}
"##;
        let toks = lex(src);
        // no unwrap ident must have survived the comments/strings
        assert!(toks.iter().all(|t| !matches!(&t.tok, Tok::Ident(s) if s == "unwrap")));
        // the fn and its name are visible
        assert!(toks.iter().any(|t| matches!(&t.tok, Tok::Ident(s) if s == "fn")));
    }

    #[test]
    fn test_spans_cover_cfg_test_items() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n fn t() { y.unwrap(); } }\n";
        let r = run_one("comm/wire.rs", src);
        assert_eq!(r.diagnostics.len(), 1, "{:?}", r.diagnostics);
        assert_eq!(r.diagnostics[0].line, 1);
    }

    #[test]
    fn cfg_not_test_is_still_linted() {
        let src = "#[cfg(not(test))]\nfn live() { x.unwrap(); }\n";
        let r = run_one("comm/wire.rs", src);
        assert_eq!(r.diagnostics.len(), 1, "{:?}", r.diagnostics);
    }

    #[test]
    fn allow_requires_justification() {
        let src = "// lint: allow(panic-free-boundary)\nfn f() { x.unwrap(); }\n";
        let r = run_one("comm/wire.rs", src);
        // the bare allow is a bad-allow AND the unwrap still fires
        assert!(r.diagnostics.iter().any(|d| d.rule == "bad-allow"), "{:?}", r.diagnostics);
        assert!(
            r.diagnostics.iter().any(|d| d.rule == "panic-free-boundary"),
            "{:?}",
            r.diagnostics
        );
        assert!(r.allows.is_empty());
    }

    #[test]
    fn justified_allow_suppresses_and_is_reported() {
        let src = "// lint: allow(panic-free-boundary) — infallible by construction\n\
                   fn f() { x.unwrap(); }\n";
        let r = run_one("comm/wire.rs", src);
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
        assert_eq!(r.allows.len(), 1);
        assert!(r.allows[0].used);
        assert_eq!(r.allows[0].justification, "infallible by construction");
    }

    #[test]
    fn unknown_rule_in_allow_is_bad() {
        let src = "// lint: allow(no-such-rule) — because\nfn f() {}\n";
        let r = run_one("comm/wire.rs", src);
        assert_eq!(r.diagnostics.len(), 1);
        assert_eq!(r.diagnostics[0].rule, "bad-allow");
    }

    #[test]
    fn scope_prefixes_gate_rules() {
        // unwrap outside a boundary module is fine
        let r = run_one("model/mod.rs", "fn f() { x.unwrap(); }\n");
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
        // mul_add outside kernel modules is fine
        let r = run_one("session/spec.rs", "fn f(a: f32) -> f32 { a.mul_add(2.0, 1.0) }\n");
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
    }

    #[test]
    fn trait_method_declarations_have_no_body() {
        let src = "trait T { fn progress(&self, rank: usize) -> bool; }\n";
        let r = run_one("comm/mod.rs", src);
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
    }
}
