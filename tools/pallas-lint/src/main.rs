//! `scalegnn-lint` — command-line front end for [`pallas_lint`].
//!
//! ```text
//! scalegnn-lint [--json] [ROOT]
//! ```
//!
//! `ROOT` defaults to the first of `rust/src`, `src`, `../rust/src` that
//! exists, so the binary works from the workspace root, from `rust/`, and
//! from `tools/pallas-lint/`.  Exit status: 0 clean, 1 diagnostics
//! reported, 2 internal error (unreadable tree, bad usage).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                println!("usage: scalegnn-lint [--json] [ROOT]");
                println!("lint a Rust source tree against the scalegnn invariants");
                return ExitCode::SUCCESS;
            }
            a if a.starts_with('-') => {
                eprintln!("scalegnn-lint: unknown flag {a} (try --help)");
                return ExitCode::from(2);
            }
            a => {
                if root.is_some() {
                    eprintln!("scalegnn-lint: more than one ROOT given");
                    return ExitCode::from(2);
                }
                root = Some(PathBuf::from(a));
            }
        }
    }
    let root = match root {
        Some(r) => r,
        None => {
            let candidates = ["rust/src", "src", "../rust/src", "../../rust/src"];
            match candidates.iter().map(PathBuf::from).find(|p| p.is_dir()) {
                Some(p) => p,
                None => {
                    eprintln!(
                        "scalegnn-lint: no source root found (tried {}); pass one explicitly",
                        candidates.join(", ")
                    );
                    return ExitCode::from(2);
                }
            }
        }
    };
    let cfg = pallas_lint::Config::repo();
    let report = match pallas_lint::lint_tree(&root, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("scalegnn-lint: {e}");
            return ExitCode::from(2);
        }
    };
    if json {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
        if report.diagnostics.is_empty() {
            eprintln!(
                "scalegnn-lint: clean ({} allow(s) in effect)",
                report.allows.len()
            );
        } else {
            eprintln!(
                "scalegnn-lint: {} diagnostic(s)",
                report.diagnostics.len()
            );
        }
    }
    if report.diagnostics.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
