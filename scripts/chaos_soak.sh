#!/usr/bin/env bash
# Chaos soak: drive a real multi-process PMM world (coordinator + 2 rank
# processes over a Unix socket) under seeded fault injection and prove
# the no-hang / recoverability guarantees end to end:
#
#   * every process runs under `timeout` — exit 124 anywhere means a
#     blocking wait escaped the deadline discipline and the soak FAILS;
#   * a failed world must print the structured `failure origin` line on
#     the coordinator's stdout;
#   * a failed world relaunched with --resume (chaos disarmed) must land
#     on the clean loss curve bit for bit from the resume step onward —
#     unless the fault fired before the first snapshot, in which case
#     the resume must fail with the descriptive no-valid-snapshot error.
#
# Seeds use the `drop` chaos mode (fail-stop at a schedule-determined
# collective), so an all-clean seed implies a bitwise-clean curve and
# any injected fault is fatal to generation 1.  Delay/stall/corrupt
# modes are exercised per-commit by `cargo test --test
# transport_conformance` and `--test chaos`; this script is about real
# OS processes, real sockets, and real relaunches.
#
# Env knobs: BIN (release bin dir), SEEDS, RATE, HARD_TIMEOUT_S, WORK.
set -u

BIN="${BIN:-target/release}"
SEEDS="${SEEDS:-11 22 33 44 55 66 77 88}"
RATE="${RATE:-0.02}"
STEPS=12
HARD_TIMEOUT_S="${HARD_TIMEOUT_S:-240}"
WORK="${WORK:-$(mktemp -d)}"

TRAIN=("$BIN/scalegnn" pmm-train --dataset tiny --grid 1x2x1x1
       --steps "$STEPS" --lr 5e-3 --seed 42)
CKPT_FLAGS=(--checkpoint-every 2 --checkpoint-keep 4)

fail() {
    echo "chaos-soak: FAIL: $*" >&2
    exit 1
}

# Curve comparator: `full` = bitwise-identical curves, `tail` = the
# resumed curve must equal the clean curve from its own first step on.
CMP="$WORK/compare.py"
cat > "$CMP" <<'EOF'
import json, sys
mode, clean_path, got_path = sys.argv[1], sys.argv[2], sys.argv[3]
clean = json.load(open(clean_path))["report"]["loss_curve"]
got = json.load(open(got_path))["report"]["loss_curve"]
assert clean and got, "a run recorded no loss curve"
if mode == "full":
    assert got == clean, "chaos-free run diverged from the clean curve"
    print(f"ok: {len(got)} steps bitwise identical")
else:
    k = got[0][0]
    assert got[-1][0] == clean[-1][0], "resumed run did not reach the last step"
    assert got == clean[k:], f"resumed tail diverged from the clean curve at step {k}"
    print(f"ok: replayed from step {k}, {len(got)} steps bitwise identical")
EOF

echo "chaos-soak: work dir $WORK, seeds [$SEEDS], rate $RATE, drop mode"

timeout "$HARD_TIMEOUT_S" "${TRAIN[@]}" --stats-json "$WORK/clean.json" \
    > "$WORK/clean.log" 2>&1 \
    || fail "clean in-process reference run did not exit 0 (log: $WORK/clean.log)"

clean_n=0 recovered_n=0 fatal_n=0
for seed in $SEEDS; do
    d="$WORK/seed-$seed"
    mkdir -p "$d"

    # generation 1: chaos armed on both ranks, same seed => same schedule
    sock="$d/gen1.sock"
    timeout "$HARD_TIMEOUT_S" "$BIN/scalegnn-coord" --grid 1x2x1x1 --unix "$sock" \
        --wait-timeout-ms 4000 > "$d/coord1.log" 2>&1 &
    c=$!
    timeout "$HARD_TIMEOUT_S" "${TRAIN[@]}" --transport "unix:$sock" --rank 1 \
        --chaos "seed=$seed,rate=$RATE,modes=drop" --wait-timeout-ms 2000 \
        --checkpoint-dir "$d/ckpts" "${CKPT_FLAGS[@]}" > "$d/rank1.gen1.log" 2>&1 &
    r1=$!
    timeout "$HARD_TIMEOUT_S" "${TRAIN[@]}" --transport "unix:$sock" --rank 0 \
        --chaos "seed=$seed,rate=$RATE,modes=drop" --wait-timeout-ms 2000 \
        --checkpoint-dir "$d/ckpts" "${CKPT_FLAGS[@]}" \
        --stats-json "$d/gen1.json" > "$d/rank0.gen1.log" 2>&1
    s0=$?
    wait "$r1"; s1=$?
    wait "$c"; sc=$?
    for s in "$s0" "$s1" "$sc"; do
        [ "$s" -eq 124 ] && fail \
            "seed $seed: a gen-1 process hit the ${HARD_TIMEOUT_S}s wall clock — a wait escaped its deadline (logs: $d)"
    done

    if [ "$s0" -eq 0 ] && [ "$s1" -eq 0 ] && [ "$sc" -eq 0 ]; then
        # the schedule never rolled a drop: the curve must be untouched
        python3 "$CMP" full "$WORK/clean.json" "$d/gen1.json" \
            || fail "seed $seed: chaos-free world diverged from the clean curve"
        clean_n=$((clean_n + 1))
        continue
    fi

    grep -q "failure origin" "$d/coord1.log" \
        || fail "seed $seed: world failed but the coordinator printed no failure origin (log: $d/coord1.log)"

    # generation 2: fresh coordinator, chaos disarmed, --resume from the
    # shared snapshot dir
    sock="$d/gen2.sock"
    timeout "$HARD_TIMEOUT_S" "$BIN/scalegnn-coord" --grid 1x2x1x1 --unix "$sock" \
        --wait-timeout-ms 4000 > "$d/coord2.log" 2>&1 &
    c=$!
    timeout "$HARD_TIMEOUT_S" "${TRAIN[@]}" --transport "unix:$sock" --rank 1 \
        --checkpoint-dir "$d/ckpts" "${CKPT_FLAGS[@]}" --resume \
        > "$d/rank1.gen2.log" 2>&1 &
    r1=$!
    timeout "$HARD_TIMEOUT_S" "${TRAIN[@]}" --transport "unix:$sock" --rank 0 \
        --checkpoint-dir "$d/ckpts" "${CKPT_FLAGS[@]}" --resume \
        --stats-json "$d/gen2.json" > "$d/rank0.gen2.log" 2>&1
    s0=$?
    wait "$r1"; s1=$?
    for s in "$s0" "$s1"; do
        [ "$s" -eq 124 ] && fail \
            "seed $seed: a resumed rank hit the ${HARD_TIMEOUT_S}s wall clock — a wait escaped its deadline (logs: $d)"
    done

    if [ "$s0" -eq 0 ] && [ "$s1" -eq 0 ]; then
        wait "$c"; sc=$?
        [ "$sc" -eq 0 ] || fail "seed $seed: resumed ranks exited 0 but the coordinator exited $sc"
        python3 "$CMP" tail "$WORK/clean.json" "$d/gen2.json" \
            || fail "seed $seed: recovered curve diverged from the clean one"
        recovered_n=$((recovered_n + 1))
    else
        # legitimate only when the drop fired before the first snapshot;
        # the ranks bail before registering, so reap the idle coordinator
        kill "$c" 2> /dev/null
        wait "$c" 2> /dev/null
        grep -q "no snapshot step is valid" "$d/rank0.gen2.log" "$d/rank1.gen2.log" \
            || fail "seed $seed: resume failed for a reason other than fatal-before-first-snapshot (logs: $d)"
        fatal_n=$((fatal_n + 1))
    fi
done

injected=$((recovered_n + fatal_n))
[ "$injected" -gt 0 ] \
    || fail "no seed injected a fault — raise RATE so the soak exercises recovery"
echo "chaos-soak: ok — $clean_n clean, $recovered_n recovered bitwise, $fatal_n fatal before the first snapshot (no hangs)"
