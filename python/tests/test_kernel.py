"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-multiples of the preferred block
sizes, so the adaptive block picker is exercised) and asserts allclose for
both the forward values and the custom-VJP gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gcn_kernels as K
from compile.kernels import ref as R

DIMS = st.integers(min_value=1, max_value=96)


def _arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = _arr(rng, m, k), _arr(rng, k, n)
    got = K.matmul(x, y)
    want = R.matmul(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(b=DIMS, d=DIMS, seed=st.integers(0, 2**31 - 1))
def test_spmm_matches_ref(b, d, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(
        (rng.random((b, b)) * (rng.random((b, b)) < 0.3)).astype(np.float32)
    )
    x = _arr(rng, b, d)
    np.testing.assert_allclose(K.spmm(a, x), R.spmm(a, x), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(b=DIMS, d=st.integers(2, 64), seed=st.integers(0, 2**31 - 1))
def test_gcn_update_matches_ref(b, d, seed):
    rng = np.random.default_rng(seed)
    h, w = _arr(rng, b, d), _arr(rng, d, d)
    g = _arr(rng, d)
    res = _arr(rng, b, d)
    mask = jnp.asarray((rng.random((b, d)) > 0.4).astype(np.float32) / 0.6)
    got = K.gcn_update(h, w, g, res, mask)
    want = R.gcn_update(h, w, g, res, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(2, 48), d=st.integers(2, 32), seed=st.integers(0, 2**31 - 1))
def test_gcn_update_gradients_match_ref(b, d, seed):
    rng = np.random.default_rng(seed)
    h, w = _arr(rng, b, d), _arr(rng, d, d)
    g = _arr(rng, d)
    res = _arr(rng, b, d)
    mask = jnp.asarray((rng.random((b, d)) > 0.4).astype(np.float32) / 0.6)

    def f_pallas(h, w, g, res):
        return jnp.sum(jnp.tanh(K.gcn_update(h, w, g, res, mask)))

    def f_ref(h, w, g, res):
        return jnp.sum(jnp.tanh(R.gcn_update(h, w, g, res, mask)))

    got = jax.grad(f_pallas, argnums=(0, 1, 2, 3))(h, w, g, res)
    want = jax.grad(f_ref, argnums=(0, 1, 2, 3))(h, w, g, res)
    for gg, ww in zip(got, want):
        np.testing.assert_allclose(gg, ww, rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(2, 48), d=st.integers(2, 32), seed=st.integers(0, 2**31 - 1))
def test_spmm_gradient_uses_transpose(b, d, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(
        (rng.random((b, b)) * (rng.random((b, b)) < 0.3)).astype(np.float32)
    )
    x = _arr(rng, b, d)

    def f_pallas(x):
        return jnp.sum(K.spmm(a, x) ** 2)

    def f_ref(x):
        return jnp.sum(R.spmm(a, x) ** 2)

    np.testing.assert_allclose(
        jax.grad(f_pallas)(x), jax.grad(f_ref)(x), rtol=1e-4, atol=1e-4
    )


def test_block_picker_divides():
    for n in range(1, 400):
        b = K._block(n, 128)
        assert 1 <= b <= min(n, 128) and n % b == 0


def test_matmul_exact_on_block_multiple_shapes():
    rng = np.random.default_rng(0)
    x, y = _arr(rng, 256, 128), _arr(rng, 128, 256)
    np.testing.assert_allclose(
        K.matmul(x, y), R.matmul(x, y), rtol=1e-5, atol=1e-5
    )


def test_rmsnorm_eps_guards_zero_rows():
    z = jnp.zeros((4, 8), jnp.float32)
    w = jnp.eye(8, dtype=jnp.float32)
    g = jnp.ones(8, jnp.float32)
    out = K.gcn_update(z, w, g, z, jnp.ones((4, 8), jnp.float32))
    assert bool(jnp.all(jnp.isfinite(out)))
