"""Layer-2 correctness: model shapes, Pallas-vs-oracle equality on the full
train step, optimizer semantics, and the grad_step+adam_apply decomposition
used by the data-parallel trainer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(batch=24, d_in=12, d_h=16, d_out=5, layers=2, dropout=0.5)


def _inputs(cfg, seed=0):
    rng = np.random.default_rng(seed)
    B = cfg.batch
    a = jnp.asarray(
        (rng.random((B, B)) * (rng.random((B, B)) < 0.3)).astype(np.float32)
    )
    x = jnp.asarray(rng.normal(size=(B, cfg.d_in)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, cfg.d_out, B).astype(np.int32))
    wm = jnp.asarray((rng.random(B) < 0.8).astype(np.float32))
    return a, x, y, wm


def test_param_shapes_and_names_align():
    shapes, names = CFG.param_shapes(), CFG.param_names()
    assert len(shapes) == len(names) == CFG.n_params
    assert shapes[0] == (CFG.d_in, CFG.d_h)
    assert shapes[-1] == (CFG.d_h, CFG.d_out)
    for l in range(CFG.layers):
        assert shapes[1 + 2 * l] == (CFG.d_h, CFG.d_h)
        assert shapes[2 + 2 * l] == (CFG.d_h,)


def test_init_params_deterministic():
    p1, p2 = M.init_params(CFG, 7), M.init_params(CFG, 7)
    p3 = M.init_params(CFG, 8)
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)
    assert any(not np.array_equal(a, b) for a, b in zip(p1, p3))


def test_forward_logits_shape_and_finite():
    a, x, _, _ = _inputs(CFG)
    params = M.init_params(CFG, 0)
    logits = M.forward(CFG, params, a, x, jax.random.PRNGKey(0), train=False)
    assert logits.shape == (CFG.batch, CFG.d_out)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_pallas_matches_ref():
    a, x, _, _ = _inputs(CFG)
    params = M.init_params(CFG, 0)
    k = jax.random.PRNGKey(3)
    lp = M.forward(CFG, params, a, x, k, train=True, use_pallas=True)
    lr_ = M.forward(CFG, params, a, x, k, train=True, use_pallas=False)
    np.testing.assert_allclose(lp, lr_, rtol=1e-4, atol=1e-4)


def test_train_step_pallas_matches_ref_over_steps():
    a, x, y, wm = _inputs(CFG)
    params = M.init_params(CFG, 0)
    zeros = [jnp.zeros_like(p) for p in params]
    sp = M.make_train_step(CFG, use_pallas=True)
    sr = M.make_train_step(CFG, use_pallas=False)
    st_p = [*params, *zeros, *zeros]
    st_r = [*params, *zeros, *zeros]
    t = jnp.float32(0)
    for i in range(3):
        k = jax.random.PRNGKey(i)
        op = sp(a, x, y, wm, k, jnp.float32(1e-2), t, *st_p)
        orf = sr(a, x, y, wm, k, jnp.float32(1e-2), t, *st_r)
        np.testing.assert_allclose(op[0], orf[0], rtol=1e-4, atol=1e-5)
        t = op[2]
        st_p, st_r = list(op[3:]), list(orf[3:])
    for pa, pb in zip(st_p, st_r):
        np.testing.assert_allclose(pa, pb, rtol=1e-3, atol=1e-4)


def test_loss_decreases_on_fixed_batch():
    a, x, y, wm = _inputs(CFG, seed=5)
    params = M.init_params(CFG, 1)
    zeros = [jnp.zeros_like(p) for p in params]
    step = jax.jit(M.make_train_step(CFG))
    state = [*params, *zeros, *zeros]
    t = jnp.float32(0)
    losses = []
    for i in range(20):
        out = step(a, x, y, wm, jax.random.PRNGKey(i), jnp.float32(5e-3), t, *state)
        losses.append(float(out[0]))
        t, state = out[2], list(out[3:])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8


def test_grad_step_plus_adam_apply_equals_train_step():
    a, x, y, wm = _inputs(CFG, seed=9)
    params = M.init_params(CFG, 2)
    zeros = [jnp.zeros_like(p) for p in params]
    k = jax.random.PRNGKey(11)
    lr, t = jnp.float32(1e-2), jnp.float32(0)
    fused = M.make_train_step(CFG)(a, x, y, wm, k, lr, t, *params, *zeros, *zeros)
    gout = M.make_grad_step(CFG)(a, x, y, wm, k, *params)
    np.testing.assert_allclose(gout[0], fused[0], rtol=1e-5)
    grads = list(gout[2:])
    aout = M.make_adam_apply(CFG)(lr, t, *params, *grads, *zeros, *zeros)
    n = CFG.n_params
    for pa, pb in zip(aout[1 : 1 + n], fused[3 : 3 + n]):
        np.testing.assert_allclose(pa, pb, rtol=1e-4, atol=1e-6)


def test_masked_loss_ignores_unmasked_vertices():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(8, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, 8).astype(np.int32))
    wm = jnp.asarray([1, 1, 0, 0, 0, 0, 0, 0], jnp.float32)
    l1, _ = M.masked_loss_acc(logits, y, wm)
    y2 = y.at[4].set((int(y[4]) + 1) % 3)  # change only a masked-out label
    l2, _ = M.masked_loss_acc(logits, y2, wm)
    np.testing.assert_allclose(l1, l2)


def test_dropout_keys_change_loss_but_eval_is_deterministic():
    a, x, y, wm = _inputs(CFG)
    params = M.init_params(CFG, 0)
    l1, _ = M.loss_fn(CFG, params, a, x, y, wm, jax.random.PRNGKey(0))
    l2, _ = M.loss_fn(CFG, params, a, x, y, wm, jax.random.PRNGKey(1))
    assert not np.isclose(float(l1), float(l2))
    ev = M.make_eval_logits(CFG)
    np.testing.assert_array_equal(ev(a, x, *params)[0], ev(a, x, *params)[0])


def test_adam_update_moves_against_gradient():
    params = [jnp.ones((4, 4), jnp.float32)]
    grads = [jnp.ones((4, 4), jnp.float32)]
    zeros = [jnp.zeros((4, 4), jnp.float32)]
    cfg = M.ModelConfig(batch=1, d_in=1, d_h=1, d_out=1, layers=0)
    new_p, _, _, t1 = M.adam_update(cfg, params, grads, zeros, zeros, jnp.float32(0), 0.1)
    assert float(t1) == 1.0
    assert bool(jnp.all(new_p[0] < params[0]))


@pytest.mark.parametrize("family", ["train_step", "grad_step", "eval_logits"])
def test_aot_example_args_match_eval_shape(family):
    from compile import aot

    fn = aot._fn(CFG, family, use_pallas=False)
    args = aot._example_args(CFG, family)
    out = jax.eval_shape(fn, *args)
    assert len(out) >= 1


SPARSE_CFG = M.ModelConfig(
    batch=24, d_in=12, d_h=16, d_out=5, layers=2, dropout=0.5, edge_cap=256
)


def _edges_of(a, cap):
    dst, src = np.nonzero(np.asarray(a))
    val = np.asarray(a)[dst, src].astype(np.float32)
    pad = cap - len(val)
    assert pad >= 0
    return (
        jnp.asarray(np.concatenate([src.astype(np.int32), np.zeros(pad, np.int32)])),
        jnp.asarray(np.concatenate([dst.astype(np.int32), np.zeros(pad, np.int32)])),
        jnp.asarray(np.concatenate([val, np.zeros(pad, np.float32)])),
    )


def test_spmm_edges_matches_dense():
    a, x, _, _ = _inputs(CFG)
    src, dst, val = _edges_of(a, 256)
    h = jnp.asarray(np.random.default_rng(0).normal(size=(24, 7)).astype(np.float32))
    got = M.spmm_edges(src, dst, val, h, 24)
    want = jnp.matmul(a, h)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sparse_train_step_matches_dense_train_step():
    a, x, y, wm = _inputs(CFG)
    src, dst, val = _edges_of(a, SPARSE_CFG.edge_cap)
    params = M.init_params(CFG, 3)
    zeros = [jnp.zeros_like(p) for p in params]
    k = jax.random.PRNGKey(5)
    lr, t = jnp.float32(1e-2), jnp.float32(0)
    dense = M.make_train_step(CFG)(a, x, y, wm, k, lr, t, *params, *zeros, *zeros)
    sparse = M.make_train_step(SPARSE_CFG)(
        src, dst, val, x, y, wm, k, lr, t, *params, *zeros, *zeros
    )
    np.testing.assert_allclose(sparse[0], dense[0], rtol=1e-5, atol=1e-6)
    n = CFG.n_params
    for pa, pb in zip(sparse[3 : 3 + n], dense[3 : 3 + n]):
        np.testing.assert_allclose(pa, pb, rtol=1e-4, atol=1e-6)


def test_sparse_padding_is_inert():
    a, x, y, wm = _inputs(CFG)
    src, dst, val = _edges_of(a, SPARSE_CFG.edge_cap)
    params = M.init_params(CFG, 3)
    k = jax.random.PRNGKey(5)
    l1 = M.loss_fn(SPARSE_CFG, params, (src, dst, val), x, y, wm, k)[0]
    # scramble the padded tail's indices (values stay 0)
    nz = int(jnp.count_nonzero(val))
    src2 = src.at[nz:].set(7)
    dst2 = dst.at[nz:].set(13)
    l2 = M.loss_fn(SPARSE_CFG, params, (src2, dst2, val), x, y, wm, k)[0]
    np.testing.assert_allclose(l1, l2)
