"""Layer-2 JAX model for ScaleGNN: the paper's GCN (§III).

Architecture (Fig. 2): input projection (GEMM) -> L x [GCN conv (SpMM +
GEMM) -> RMSNorm -> ReLU -> dropout -> residual] -> output head (GEMM) ->
masked cross-entropy.  The hot ops call the Layer-1 Pallas kernels
(``kernels.gcn_kernels``); ``use_pallas=False`` swaps in the pure-jnp
oracles (``kernels.ref``) for cross-checking.

The whole training step (forward, backward via jax.grad through the
kernels' custom VJPs, Adam update) is a single jittable function that
``aot.py`` lowers to one HLO-text artifact per model configuration; the
Rust coordinator executes it via PJRT with donated parameter buffers and
never re-enters Python.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import gcn_kernels as K
from compile.kernels import ref as R

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape/hyperparameter bundle baked into one artifact."""

    batch: int  # B: mini-batch vertex count (rows of the induced subgraph)
    d_in: int  # raw feature dim
    d_h: int  # hidden dim (uniform across layers, enables residuals)
    d_out: int  # number of classes
    layers: int = 3  # L
    dropout: float = 0.5
    weight_decay: float = 0.0
    # >0: the adjacency arrives as a padded edge list of this capacity and
    # aggregation is a gather + segment-sum (the CPU-efficient lowering:
    # the induced mini-batch subgraph is extremely sparse, §III-D).
    # 0: dense B x B adjacency through the Pallas matmul (the TPU/MXU
    # schedule, DESIGN.md §Hardware-Adaptation).
    edge_cap: int = 0

    @property
    def n_params(self) -> int:
        # W_in, (W_l, g_l) per layer, W_out
        return 2 + 2 * self.layers

    def param_shapes(self) -> List[Tuple[int, ...]]:
        shapes: List[Tuple[int, ...]] = [(self.d_in, self.d_h)]
        for _ in range(self.layers):
            shapes.append((self.d_h, self.d_h))
            shapes.append((self.d_h,))
        shapes.append((self.d_h, self.d_out))
        return shapes

    def param_names(self) -> List[str]:
        names = ["w_in"]
        for l in range(self.layers):
            names += [f"w_{l}", f"g_{l}"]
        names.append("w_out")
        return names


def init_params(cfg: ModelConfig, seed: int) -> List[jnp.ndarray]:
    """Glorot-uniform weights, unit RMSNorm scales (deterministic in seed)."""
    key = jax.random.PRNGKey(seed)
    params: List[jnp.ndarray] = []
    for shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in, fan_out = shape
            lim = (6.0 / (fan_in + fan_out)) ** 0.5
            params.append(
                jax.random.uniform(sub, shape, jnp.float32, -lim, lim)
            )
    return params


def spmm_edges(src, dst, val, h, batch):
    """Sparse aggregation over a padded edge list (Eq. 5): padding entries
    carry val=0 so they contribute nothing.  Differentiates natively
    (gather/scatter-add have built-in JVP/VJP rules); the backward pass is
    the transposed scatter, exactly Eq. 17."""
    gathered = h[src] * val[:, None]
    return jax.ops.segment_sum(gathered, dst, num_segments=batch)


def forward(
    cfg: ModelConfig,
    params: Sequence[jnp.ndarray],
    a,
    x: jnp.ndarray,
    key: jnp.ndarray,
    train: bool,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Logits for the mini-batch (Eqs. 4-11).  ``a`` is the dense B x B
    adjacency, or a ``(src, dst, val)`` padded edge-list triple when
    ``cfg.edge_cap > 0``."""
    mm = K.matmul if use_pallas else R.matmul
    sp = K.spmm if use_pallas else R.spmm
    upd = K.gcn_update if use_pallas else R.gcn_update

    h = mm(x, params[0])  # input projection (Eq. 4)
    for l in range(cfg.layers):
        w, g = params[1 + 2 * l], params[2 + 2 * l]
        if cfg.edge_cap > 0:
            src, dst, val = a
            h_agg = spmm_edges(src, dst, val, h, cfg.batch)  # Eq. 5
        else:
            h_agg = sp(a, h)  # Eq. 5
        if train and cfg.dropout > 0.0:
            key, sub = jax.random.split(key)
            keep = 1.0 - cfg.dropout
            mask = (
                jax.random.bernoulli(sub, keep, (cfg.batch, cfg.d_h)).astype(
                    jnp.float32
                )
                / keep
            )
        else:
            mask = jnp.ones((cfg.batch, cfg.d_h), jnp.float32)
        h = upd(h_agg, w, g, h, mask)  # Eqs. 6-10 fused
    return mm(h, params[-1])  # output head (Eq. 11)


def masked_loss_acc(
    logits: jnp.ndarray, y: jnp.ndarray, wmask: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-entropy + accuracy over the masked (training-split) vertices.

    ``wmask`` is 1.0 for vertices that contribute to the loss: the sampled
    train vertices for ScaleGNN/GraphSAINT, only the target vertices for the
    GraphSAGE baseline (whose batch also contains support vertices)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(jnp.sum(wmask), 1.0)
    loss = jnp.sum(nll * wmask) / denom
    correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
    acc = jnp.sum(correct * wmask) / denom
    return loss, acc


def loss_fn(cfg, params, a, x, y, wmask, key, use_pallas=True):
    logits = forward(cfg, params, a, x, key, train=True, use_pallas=use_pallas)
    loss, acc = masked_loss_acc(logits, y, wmask)
    return loss, acc


def adam_update(cfg, params, grads, m, v, t, lr):
    """Bias-corrected Adam with decoupled weight decay (Eqs. 13-19 feed the
    grads; the update itself is standard)."""
    t1 = t + 1.0
    b1t = 1.0 - ADAM_B1**t1
    b2t = 1.0 - ADAM_B2**t1
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        step = lr * (mi / b1t) / (jnp.sqrt(vi / b2t) + ADAM_EPS)
        if cfg.weight_decay > 0.0:
            step = step + lr * cfg.weight_decay * p
        new_p.append(p - step)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, t1


def make_train_step(cfg: ModelConfig, use_pallas: bool = True):
    """Returns the per-step artifact function
    ``f(<adj>, x, y, wmask, key, lr, t, *params, *m, *v)`` ->
    ``(loss, acc, t', *params', *m', *v')`` where ``<adj>`` is the dense
    B x B matrix, or ``src, dst, val`` when ``cfg.edge_cap > 0``."""
    n = cfg.n_params

    def body(a, x, y, wmask, key, lr, t, state):
        params = list(state[:n])
        m = list(state[n : 2 * n])
        v = list(state[2 * n : 3 * n])
        (loss, acc), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, a, x, y, wmask, key, use_pallas),
            has_aux=True,
        )(params)
        new_p, new_m, new_v, t1 = adam_update(cfg, params, grads, m, v, t, lr)
        return (loss, acc, t1, *new_p, *new_m, *new_v)

    if cfg.edge_cap > 0:
        def train_step(src, dst, val, x, y, wmask, key, lr, t, *state):
            return body((src, dst, val), x, y, wmask, key, lr, t, state)
    else:
        def train_step(a, x, y, wmask, key, lr, t, *state):
            return body(a, x, y, wmask, key, lr, t, state)
    return train_step


def make_grad_step(cfg: ModelConfig, use_pallas: bool = True):
    """Returns ``f(a, x, y, wmask, key, *params)`` -> ``(loss, acc, *grads)``.

    Used by the data-parallel trainer variant that all-reduces raw gradients
    across DP groups *before* the (rank-local, replicated) Adam update."""
    n = cfg.n_params
    del n

    def body(a, x, y, wmask, key, params):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, list(p), a, x, y, wmask, key, use_pallas),
            has_aux=True,
        )(list(params))
        return (loss, acc, *grads)

    if cfg.edge_cap > 0:
        def grad_step(src, dst, val, x, y, wmask, key, *params):
            return body((src, dst, val), x, y, wmask, key, params)
    else:
        def grad_step(a, x, y, wmask, key, *params):
            return body(a, x, y, wmask, key, params)
    return grad_step


def make_adam_apply(cfg: ModelConfig):
    """Returns ``f(lr, t, *params, *grads, *m, *v)`` ->
    ``(t', *params', *m', *v')`` — applied after the DP gradient
    all-reduce."""
    n = cfg.n_params

    def adam_apply(lr, t, *state):
        params = list(state[:n])
        grads = list(state[n : 2 * n])
        m = list(state[2 * n : 3 * n])
        v = list(state[3 * n : 4 * n])
        new_p, new_m, new_v, t1 = adam_update(cfg, params, grads, m, v, t, lr)
        return (t1, *new_p, *new_m, *new_v)

    return adam_apply


def make_eval_logits(cfg: ModelConfig, use_pallas: bool = True):
    """Returns ``f(<adj>, x, *params) -> (logits,)`` (dropout off)."""

    def body(a, x, params):
        key = jax.random.PRNGKey(0)
        return (
            forward(
                cfg, list(params), a, x, key, train=False, use_pallas=use_pallas
            ),
        )

    if cfg.edge_cap > 0:
        def eval_logits(src, dst, val, x, *params):
            return body((src, dst, val), x, params)
    else:
        def eval_logits(a, x, *params):
            return body(a, x, params)
    return eval_logits


def make_local_gemm(m: int, k: int, n: int):
    """Rank-local GEMM primitive for the 3D-PMM engine's PJRT path."""

    def local_gemm(x, y):
        return (K.matmul(x, y),)

    del m, k, n
    return local_gemm


def make_fused_update(cfg: ModelConfig):
    """Standalone fused layer-tail primitive (PMM engine PJRT path)."""

    def fused(h, w, g, res, mask):
        return (K.gcn_update(h, w, g, res, mask),)

    return fused
