"""Layer-1 Pallas kernels for ScaleGNN.

All kernels run with ``interpret=True`` so they lower to plain HLO ops that
the CPU PJRT client (xla_extension 0.5.1) can execute.  On a real TPU the
same BlockSpecs express the HBM->VMEM tiling schedule; see DESIGN.md §8 for
the VMEM-footprint / MXU-utilization estimates.

Kernels
-------
``matmul``        blocked dense matmul (used for SpMM on the dense-ified
                  induced mini-batch adjacency, and for the projections).
``gcn_update``    fused GCN layer epilogue: ``H_agg @ W`` then RMSNorm with a
                  learned scale, ReLU, dropout (precomputed mask) and the
                  residual add — one VMEM residency, zero intermediate HBM
                  round-trips (paper §V-C's kernel fusion, TPU-shaped).

Both are wrapped in ``jax.custom_vjp`` so the Layer-2 model can be
differentiated; the backward passes implement the paper's Eqs. 13-17 as
matmuls (re-using the Pallas matmul where it is one) plus the element-wise
mask/RMSNorm gradients.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RMS_EPS = 1e-6

# Preferred tile edge.  128 matches the TPU MXU/VMEM schedule documented in
# DESIGN.md §8; the CPU artifacts are lowered with a large target (see
# aot.py) because interpret-mode pallas serializes the grid into an XLA
# while-loop — one big dot beats 512 tiny ones on the CPU backend
# (EXPERIMENTS.md §Perf L1).
BLOCK_TARGET = int(os.environ.get("SCALEGNN_BLOCK_TARGET", "128"))


def _block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (block-shape picker)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Blocked matmul kernel
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Grid = (M/bm, N/bn, K/bk); the output block is revisited across the K
    axis and accumulates partial products in place (VMEM-resident on TPU)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pallas(x: jax.Array, y: jax.Array, bm=None, bn=None, bk=None):
    """Blocked ``x @ y`` via Pallas; block shapes adapt to any input shape
    via :func:`_block` so every grid step sees a full tile."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm = _block(m, bm or BLOCK_TARGET)
    bn = _block(n, bn or BLOCK_TARGET)
    bk = _block(k, bk or BLOCK_TARGET)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


# ---------------------------------------------------------------------------
# custom-vjp matmul wrapper (a.k.a. SpMM on the dense-ified adjacency)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def matmul(x, y):
    return matmul_pallas(x, y)


def _matmul_fwd(x, y):
    return matmul_pallas(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # dX = g @ Y^T ; dY = X^T @ g   (Eqs. 13-17 GEMM/SpMM gradients)
    return matmul_pallas(g, y.T), matmul_pallas(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def spmm(a, x):
    """Mini-batch aggregation H = Ã_S X  (Eq. 5) on the dense-ified induced
    adjacency.  The adjacency is data (never differentiated); its cotangent
    is dropped by the custom vjp below so XLA DCEs the dead matmul."""
    return _spmm(a, x)


@jax.custom_vjp
def _spmm(a, x):
    return matmul_pallas(a, x)


def _spmm_fwd(a, x):
    return matmul_pallas(a, x), (a,)


def _spmm_bwd(res, g):
    (a,) = res
    # Backward aggregation uses A^T (Eq. 17); A itself gets a zero cotangent.
    return jnp.zeros_like(a), matmul_pallas(a.T, g)


_spmm.defvjp(_spmm_fwd, _spmm_bwd)


# ---------------------------------------------------------------------------
# Fused GCN update kernel: rmsnorm(h @ w) * g -> relu -> dropout -> +res
# ---------------------------------------------------------------------------


def _gcn_update_kernel(h_ref, w_ref, g_ref, res_ref, mask_ref, o_ref, *, nk):
    """One (bm, d_h) row-block per program.  The whole W panel and the full
    hidden dimension stay resident in VMEM so the RMSNorm row reduction and
    the element-wise epilogue fuse with the matmul."""
    acc = jnp.zeros((h_ref.shape[0], w_ref.shape[1]), jnp.float32)
    # K is the full hidden dim (<= a few hundred): a single VMEM panel.
    acc += jnp.dot(h_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    ms = jnp.mean(acc * acc, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + RMS_EPS)
    y = acc * inv * g_ref[...]
    y = jnp.maximum(y, 0.0)
    y = y * mask_ref[...]
    o_ref[...] = y + res_ref[...]


def gcn_update_pallas(h, w, g, res, mask, bm=None):
    b, dh = h.shape
    assert w.shape == (dh, dh) and res.shape == h.shape and mask.shape == h.shape
    bm = _block(b, bm or BLOCK_TARGET)
    return pl.pallas_call(
        functools.partial(_gcn_update_kernel, nk=1),
        grid=(b // bm,),
        in_specs=[
            pl.BlockSpec((bm, dh), lambda i: (i, 0)),
            pl.BlockSpec((dh, dh), lambda i: (0, 0)),
            pl.BlockSpec((1, dh), lambda i: (0, 0)),
            pl.BlockSpec((bm, dh), lambda i: (i, 0)),
            pl.BlockSpec((bm, dh), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, dh), jnp.float32),
        interpret=True,
    )(h, w, g.reshape(1, dh), res, mask)


@jax.custom_vjp
def gcn_update(h, w, g, res, mask):
    """Fused GCN layer tail (Eqs. 6-10): ``relu(rmsnorm(h@w)*g)*mask + res``.

    ``mask`` is the dropout keep-mask already scaled by ``1/(1-p)`` (ones at
    eval time), so the kernel itself is deterministic."""
    return gcn_update_pallas(h, w, g, res, mask)


def _gcn_update_fwd(h, w, g, res, mask):
    out = gcn_update_pallas(h, w, g, res, mask)
    return out, (h, w, g, mask)


def _gcn_update_bwd(saved, dout):
    h, w, g, mask = saved
    dh_dim = w.shape[0]
    # Recompute the cheap intermediates (rematerialization beats storing
    # three B x d_h tensors; see DESIGN.md §7 L2).
    xc = matmul_pallas(h, w)
    ms = jnp.mean(xc * xc, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + RMS_EPS)
    xn = xc * inv
    y = jnp.maximum(xn * g, 0.0)
    # residual path
    dres = dout
    # dropout + relu masks
    dy = dout * mask
    drelu = jnp.where(xn * g > 0.0, dy, 0.0)
    # rmsnorm backward: y = xn * g, xn = xc * inv
    dg = jnp.sum(drelu * xn, axis=0)
    dxn = drelu * g
    # d xc of xn = xc * (mean(xc^2)+eps)^-1/2
    dot = jnp.mean(dxn * xc, axis=-1, keepdims=True)
    dxc = inv * (dxn - xc * dot * inv * inv)
    # GEMM backward (Eqs. 15-16)
    dh = matmul_pallas(dxc, w.T)
    dw = matmul_pallas(h.T, dxc)
    del y, dh_dim
    return dh, dw, dg, dres, jnp.zeros_like(mask)


gcn_update.defvjp(_gcn_update_fwd, _gcn_update_bwd)
