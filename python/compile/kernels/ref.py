"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Every kernel in :mod:`gcn_kernels` has an exact counterpart here; pytest
(``python/tests/test_kernel.py``) sweeps shapes with hypothesis and asserts
``allclose``.  The Layer-2 model can also be built entirely from these
oracles (``model.forward(..., use_pallas=False)``) which is how the fused
artifacts are cross-checked.
"""

from __future__ import annotations

import jax.numpy as jnp

RMS_EPS = 1e-6


def matmul(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Dense matmul oracle."""
    return jnp.matmul(x, y)


def spmm(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Aggregation oracle: H = Ã_S X (Eq. 5) on the dense-ified adjacency."""
    return jnp.matmul(a, x)


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """RMSNorm with learned scale (Eq. 7)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (ms + RMS_EPS) ** -0.5 * g


def gcn_update(h, w, g, res, mask):
    """Fused GCN layer tail oracle (Eqs. 6-10):
    ``relu(rmsnorm(h @ w) * g) * mask + res``."""
    xc = jnp.matmul(h, w)
    xn = rmsnorm(xc, g)
    y = jnp.maximum(xn, 0.0)
    return y * mask + res
