"""AOT driver: lower the Layer-2 model to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust coordinator loads the
emitted ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and
never re-enters Python.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate
binds) rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Outputs
-------
``artifacts/<name>.hlo.txt``   one per artifact
``artifacts/manifest.json``    shapes/dtypes/param-layout for every artifact
``artifacts/golden.json``      deterministic tiny-model trajectories for the
                               Rust integration tests
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

# ---------------------------------------------------------------------------
# Registered model configurations (mirrored by rust/src/config/presets).
# ---------------------------------------------------------------------------

MODEL_CONFIGS: Dict[str, M.ModelConfig] = {
    # tiny: golden-vector tests + fast integration tests
    "tiny": M.ModelConfig(batch=32, d_in=16, d_h=16, d_out=4, layers=2,
                          dropout=0.5, edge_cap=512),
    # stand-ins for the paper's accuracy datasets (§VI-C); generous edge
    # capacity so GraphSAINT's degree-biased batches also fit
    "products_sim": M.ModelConfig(batch=1024, d_in=128, d_h=128, d_out=48,
                                  layers=3, dropout=0.5, edge_cap=16384),
    "reddit_sim": M.ModelConfig(batch=1024, d_in=128, d_h=128, d_out=40,
                                layers=3, dropout=0.5, edge_cap=16384),
    # end-to-end driver model (larger d_h/L; examples/train_e2e.rs)
    "e2e_big": M.ModelConfig(batch=1024, d_in=256, d_h=512, d_out=32,
                             layers=4, dropout=0.3, edge_cap=8192),
    # dense-adjacency variant of tiny: exercises the TPU/MXU dense-SpMM
    # schedule end to end (kept for the pallas path + golden tests)
    "tiny_dense": M.ModelConfig(batch=32, d_in=16, d_h=16, d_out=4, layers=2,
                                dropout=0.5),
}

# Which artifact families to emit per config.
FAMILIES: Dict[str, List[str]] = {
    "tiny": ["train_step", "grad_step", "adam_apply", "eval_logits"],
    "tiny_dense": ["train_step", "eval_logits"],
    "products_sim": ["train_step", "grad_step", "adam_apply", "eval_logits"],
    "reddit_sim": ["train_step", "eval_logits"],
    "e2e_big": ["train_step", "eval_logits"],
}

# Rank-local GEMM primitives for the 3D-PMM engine's PJRT path
# (m, k, n) — shard shapes used by pmm integration tests and benches.
PMM_GEMMS: List[tuple] = [
    (256, 256, 64),
    (256, 64, 64),
    (512, 128, 128),
]
# Standalone fused layer-tail primitives (b, d_h).
PMM_FUSED: List[tuple] = [(256, 64), (1024, 128)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(arr) -> Dict[str, Any]:
    return {"shape": list(arr.shape), "dtype": str(arr.dtype)}


def _example_args(cfg: M.ModelConfig, family: str):
    """Abstract example arguments (ShapeDtypeStruct) per artifact family."""
    f32 = jnp.float32
    B = cfg.batch
    sd = jax.ShapeDtypeStruct
    if cfg.edge_cap > 0:
        e = cfg.edge_cap
        adj = [sd((e,), jnp.int32), sd((e,), jnp.int32), sd((e,), f32)]
    else:
        adj = [sd((B, B), f32)]
    x = sd((B, cfg.d_in), f32)
    y = sd((B,), jnp.int32)
    wm = sd((B,), f32)
    key = sd((2,), jnp.uint32)
    lr = sd((), f32)
    t = sd((), f32)
    params = [sd(s, f32) for s in cfg.param_shapes()]
    if family == "train_step":
        return [*adj, x, y, wm, key, lr, t, *params, *params, *params]
    if family == "grad_step":
        return [*adj, x, y, wm, key, *params]
    if family == "adam_apply":
        return [lr, t, *params, *params, *params, *params]
    if family == "eval_logits":
        return [*adj, x, *params]
    raise ValueError(family)


def _fn(cfg: M.ModelConfig, family: str, use_pallas: bool):
    if family == "train_step":
        return M.make_train_step(cfg, use_pallas)
    if family == "grad_step":
        return M.make_grad_step(cfg, use_pallas)
    if family == "adam_apply":
        return M.make_adam_apply(cfg)
    if family == "eval_logits":
        return M.make_eval_logits(cfg, use_pallas)
    raise ValueError(family)


def _donate(family: str, cfg: M.ModelConfig):
    """Donated argnums: parameter/optimizer buffers are updated in place on
    the PJRT side, halving peak memory of the step (DESIGN.md §7 L2)."""
    n = cfg.n_params
    adj_args = 3 if cfg.edge_cap > 0 else 1
    if family == "train_step":
        # donate params, m, v (after the batch/lr/t leading args)
        lead = adj_args + 6
        return tuple(range(lead, lead + 3 * n))
    if family == "adam_apply":
        # donate params, m, v (grads are consumed too but aliasing them to
        # outputs is not needed); params at 2..2+n, m/v at 2+2n..2+4n
        return tuple(range(2, 2 + n)) + tuple(range(2 + 2 * n, 2 + 4 * n))
    return ()


def lower_artifact(name: str, fn, example_args, out_dir: str, donate=()) -> Dict[str, Any]:
    jitted = jax.jit(fn, donate_argnums=donate)
    lowered = jitted.lower(*example_args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_avals = jax.eval_shape(fn, *example_args)
    entry = {
        "name": name,
        "file": f"{name}.hlo.txt",
        "inputs": [_spec(a) for a in example_args],
        "outputs": [_spec(o) for o in out_avals],
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        "bytes": len(text),
    }
    print(f"  {name}: {len(text)} chars, {len(example_args)} in / {len(out_avals)} out")
    return entry


def emit_golden(out_dir: str, steps: int = 4) -> None:
    """Deterministic tiny-model trajectory for Rust integration tests."""
    cfg = MODEL_CONFIGS["tiny"]
    rng = np.random.default_rng(12345)
    B = cfg.batch
    a = (rng.random((B, B)) * (rng.random((B, B)) < 0.25)).astype(np.float32)
    x = rng.normal(size=(B, cfg.d_in)).astype(np.float32)
    y = rng.integers(0, cfg.d_out, B).astype(np.int32)
    wm = np.ones(B, np.float32)
    params = M.init_params(cfg, 0)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    # padded edge list for the sparse (CPU) lowering
    # h_agg[d] += a[d, s] * h[s]: row index is the destination
    dst_e, src_e = np.nonzero(a)
    val_e = a[dst_e, src_e].astype(np.float32)
    e = cfg.edge_cap
    assert len(val_e) <= e, "golden graph exceeds edge capacity"
    pad = e - len(val_e)
    src = np.concatenate([src_e.astype(np.int32), np.zeros(pad, np.int32)])
    dst = np.concatenate([dst_e.astype(np.int32), np.zeros(pad, np.int32)])
    val = np.concatenate([val_e, np.zeros(pad, np.float32)])

    ts = jax.jit(M.make_train_step(cfg))
    ev = jax.jit(M.make_eval_logits(cfg))
    t = jnp.float32(0)
    losses, accs = [], []
    state = [*params, *m, *v]
    keys = []
    for i in range(steps):
        key = jax.random.PRNGKey(1000 + i)
        keys.append(np.asarray(key, np.uint32).tolist())
        out = ts(jnp.array(src), jnp.array(dst), jnp.array(val),
                 jnp.array(x), jnp.array(y), jnp.array(wm),
                 key, jnp.float32(1e-2), t, *state)
        losses.append(float(out[0]))
        accs.append(float(out[1]))
        t = out[2]
        state = list(out[3:])
    logits = ev(jnp.array(src), jnp.array(dst), jnp.array(val),
                jnp.array(x), *state[: cfg.n_params])[0]
    golden = {
        "config": "tiny",
        "lr": 1e-2,
        "steps": steps,
        "a": a.flatten().tolist(),
        "src": src.tolist(),
        "dst": dst.tolist(),
        "val": val.tolist(),
        "x": x.flatten().tolist(),
        "y": y.tolist(),
        "wmask": wm.tolist(),
        "keys": keys,
        "init_params": [np.asarray(p).flatten().tolist() for p in params],
        "losses": losses,
        "accs": accs,
        "final_logits_row0": np.asarray(logits)[0].tolist(),
        "final_param0_sum": float(np.asarray(state[0]).sum()),
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print(f"  golden.json: losses={['%.4f' % l for l in losses]}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="comma-separated config names")
    ap.add_argument("--no-golden", action="store_true")
    ap.add_argument("--ref", action="store_true",
                    help="lower with the pure-jnp oracle instead of Pallas")
    ap.add_argument("--tpu-blocks", action="store_true",
                    help="keep 128x128 BlockSpec tiles (TPU schedule); the "
                         "default lowers CPU artifacts with whole-matrix "
                         "blocks because interpret-mode pallas serializes "
                         "the grid (EXPERIMENTS.md §Perf L1)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    use_pallas = not args.ref
    if not args.tpu_blocks:
        from compile.kernels import gcn_kernels as _K

        _K.BLOCK_TARGET = 1 << 16

    manifest: Dict[str, Any] = {"artifacts": [], "models": {}}
    names = args.only.split(",") if args.only else list(MODEL_CONFIGS)
    for cname in names:
        cfg = MODEL_CONFIGS[cname]
        manifest["models"][cname] = {
            **dataclasses.asdict(cfg),  # includes edge_cap
            "param_shapes": [list(s) for s in cfg.param_shapes()],
            "param_names": cfg.param_names(),
        }
        print(f"[{cname}] {cfg}")
        for family in FAMILIES[cname]:
            entry = lower_artifact(
                f"{family}_{cname}",
                _fn(cfg, family, use_pallas),
                _example_args(cfg, family),
                args.out,
                donate=_donate(family, cfg),
            )
            entry["model"] = cname
            entry["family"] = family
            manifest["artifacts"].append(entry)

    # PMM local primitives
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    for (m_, k_, n_) in PMM_GEMMS:
        entry = lower_artifact(
            f"local_gemm_{m_}x{k_}x{n_}",
            M.make_local_gemm(m_, k_, n_),
            [sd((m_, k_), f32), sd((k_, n_), f32)],
            args.out,
        )
        entry["family"] = "local_gemm"
        manifest["artifacts"].append(entry)
    for (b_, dh_) in PMM_FUSED:
        cfg = M.ModelConfig(batch=b_, d_in=dh_, d_h=dh_, d_out=dh_, layers=1)
        entry = lower_artifact(
            f"fused_update_{b_}x{dh_}",
            M.make_fused_update(cfg),
            [sd((b_, dh_), f32), sd((dh_, dh_), f32), sd((dh_,), f32),
             sd((b_, dh_), f32), sd((b_, dh_), f32)],
            args.out,
        )
        entry["family"] = "fused_update"
        manifest["artifacts"].append(entry)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if not args.no_golden:
        emit_golden(args.out)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
